"""Event-level DRAM timing model for the `sim` backend (vectorized).

Two entry points, mirroring the two measurement modes of the paper's engine
module (Sec. III-C-1), both *direction-aware* — the engine has independent
read and write modules, and Sec. IV treats writes and mixed read/write
traffic as first-class workloads:

* :func:`serial_latencies` — the latency mode: exactly one outstanding
  transaction; the (i+1)-th is issued only after the i-th returns.
  Reproduces Fig. 4 (refresh spikes), Fig. 5 / Table IV (page hit / closed /
  miss), Table VI (switch distance).  ``op="write"`` adds the write-recovery
  segment (tWR) to the page-miss path: the precharge a miss requires must
  wait out the previous write's recovery.  :func:`serial_read_latencies`
  remains the read-only alias.

* :func:`throughput` — the saturating mode: the engine always asserts the
  address-valid signals, the controller reorders inside a window.  Modeled as
  a steady-state resource-bound analysis at DRAM *column-command*
  granularity:

    - data bus:       1 command (= bus_bytes) per AXI cycle,
    - bank group:     1 command per tCCD_L per bank group (tCCD_S across
                      groups) — this is what makes bank-group interleaving
                      (paper Sec. V-D) and the LSB "BG" bit of the default
                      RGBCG policy matter,
    - bank:           row activations serialize at tRC per bank; write
                      traffic extends each activation by tWR (write
                      recovery before precharge), duplex by tWR/2,
    - turnaround:     duplex traffic reverses the bus direction; the
                      modeled controller groups reads and writes within a
                      reorder window, paying one read->write plus one
                      write->read turnaround (tRTW + tWTR) per window,
    - tFAW:           at most 4 activations per tFAW window,
    - refresh:        (1 - tRFC/tREFI) de-rating,
    - scheduler:      calibrated constant inefficiency.

  ``op="read"`` reproduces the pre-write-path numbers bit-for-bit (the
  direction overheads are exactly zero).

* :func:`contended_throughput` — N engines sharing one channel /
  mini-switch port (DESIGN.md §8/§9): the engines' streams are interleaved
  (engine k over its own W-byte window at ``A + k*W``) and the shared
  stream runs through the same three bounds, so contention *emerges* from
  interleaving — row thrash in shared banks, shortened bank-group runs —
  rather than being asserted.  The *arbitration granularity* is an axis
  (``arbitration``, ``burst_beats``): ``"round_robin"`` alternates engines
  every transaction (the worst case, and the bit-identical ``burst_beats=1``
  special case of ``"burst"``), ``"burst"`` grants each engine
  ``burst_beats`` consecutive transactions per rotation (preserving
  row-buffer locality inside a grant — the lever Choi et al. 2020 show
  moves multi-PE designs from ~30% to ~90% of nominal), and
  ``"exclusive"`` serializes whole streams (each engine runs to completion
  before the next — the upper grant-size bound that ``burst`` converges to
  as ``burst_beats`` grows).  Reports the aggregate/per-engine bandwidth
  split plus per-policy queueing-delay terms; bit-identical to
  :func:`throughput` at ``num_engines=1`` under every policy.

* contended *latency* — :func:`serial_latencies` accepts the same
  ``num_engines`` / ``arbitration`` / ``burst_beats`` axes and feeds the
  per-engine queueing delay back into the per-transaction trace:
  round-robin shifts every transaction by the mean arbitration wait,
  burst grants concentrate the same mean wait onto each grant-head
  transaction (a bimodal contended distribution — the new latency classes
  `core/latency.py` classifies), and exclusive grants pay one up-front
  whole-stream wait.  ``num_engines=1`` is bit-identical to the
  uncontended trace.

Cross-channel contention — streams landing on *different* channels of the
same (or a distant) mini-switch — is the switch fabric's business, not the
DRAM's: see ``core/switch.py`` (per-mini-switch aggregate and lateral-link
capacity terms) and ``Engine.evaluate_contention(placement=...)``.

Both functions are NumPy array code end to end (DESIGN.md §3):

* Page-state classification is a segment analysis: a stable argsort groups
  the stream by bank, a shifted-array comparison finds each transaction's
  previous same-bank access, and hit/closed/miss falls out of one row
  comparison.  The only remaining Python loop in the serial model iterates
  over *refresh epochs* (~tREFI of simulated time each), not transactions.
* The throughput bounds are segment reductions over reorder-window chunks:
  per-window distinct-bank-group counts via a row-wise sort, per-window
  per-bank activation counts via ``np.bincount`` on a (window, bank) key.

The loop-based reference implementation is preserved verbatim in
:mod:`repro.core._timing_reference`; parity tests pin this module to it.

  Calibration anchors (see tests/core/test_timing_model.py):
    HBM  sequential read  B=32  -> 13.27 GB/s  (Table V)
    DDR4 sequential read  B=64  -> 18.0  GB/s  (Table V)
    HBM  B=32 W=8K  S=4K        -> ~6.7 GB/s   (Sec. V-E)
    HBM  B=32 W=256M S=4K       -> ~2.4 GB/s   (Sec. V-E)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.address_mapping import AddressMapping
from repro.core.engine_mix import EngineMix
from repro.core.hwspec import MemorySpec
from repro.core.params import RSTParams

# Page states, following Sec. V-B.
PAGE_HIT, PAGE_CLOSED, PAGE_MISS = "hit", "closed", "miss"
_STATE_NAMES = np.array((PAGE_HIT, PAGE_CLOSED, PAGE_MISS))

# Cap on how many transactions we expand when the stream is periodic.
_MAX_EXPAND = 1 << 16
# Reorder-window size (transactions) of the modeled controller.
_REORDER_WINDOW = 64

# Traffic directions of the engine module: its read module, its write
# module, or both running concurrently over one channel (Sec. III-C-1).
OPS = ("read", "write", "duplex")
# Arbitration granularities of the shared channel port (DESIGN.md §9):
# per-transaction round robin (the worst case), burst grants of
# `burst_beats` consecutive transactions per engine per rotation, and
# exclusive whole-stream grants (the serialized upper bound).
ARBITRATION_POLICIES = ("round_robin", "burst", "exclusive")
# Serial latency is one-transaction-at-a-time; a duplex direction has no
# meaning there (there is never a second in-flight transaction to turn the
# bus around for).
SERIAL_OPS = ("read", "write")


def _grant_beats(arbitration: str, burst_beats: int, txns: int) -> int:
    """Transactions one engine issues per arbitration grant.

    ``round_robin`` is defined as the one-beat grant (and rejects any other
    ``burst_beats`` so a mismatched pair fails loudly instead of silently
    meaning something else); ``burst`` grants ``burst_beats`` beats;
    ``exclusive`` grants the whole stream — equivalently ``burst`` with
    ``burst_beats >= txns``, which is exactly how ``burst`` converges to
    the serialized bound as the grant grows.  Burst grants clamp to the
    stream length: a grant cannot outlast the stream, and an unclamped
    size would inflate the grant-head wait terms past the physical
    maximum of the other engines' whole streams (the device-side kernel
    clamps identically).
    """
    if arbitration not in ARBITRATION_POLICIES:
        raise ValueError(f"unknown arbitration {arbitration!r}; valid: "
                         f"{ARBITRATION_POLICIES}")
    if burst_beats < 1:
        raise ValueError(f"burst_beats must be >= 1, got {burst_beats}")
    if arbitration != "burst" and burst_beats != 1:
        raise ValueError(
            f"burst_beats={burst_beats} only applies to the 'burst' policy; "
            f"{arbitration!r} fixes the grant size (round_robin: 1 beat, "
            f"exclusive: the whole stream)")
    if arbitration == "round_robin":
        return 1
    if arbitration == "exclusive":
        return max(1, txns)
    return min(burst_beats, max(1, txns))


def _direction_overheads(spec: MemorySpec, op: str) -> Tuple[float, float]:
    """(per-reorder-window turnaround cycles, per-activation extra cycles)
    for one traffic direction.

    Reads: zero on both axes — the read path is bit-identical to the
    pre-write-path model.  Writes: each row activation is extended by the
    write recovery tWR (the precharge closing the row must wait it out).
    Duplex: half the activations are writes (tWR/2 on average), and the
    modeled controller groups reads and writes inside each reorder window,
    paying one read->write plus one write->read bus turnaround per window.
    """
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; valid: {OPS}")
    if op == "read":
        return 0.0, 0.0
    wr_cyc = spec.ns_to_cycles(spec.t_wr_ns)
    if op == "write":
        return 0.0, wr_cyc
    turnaround = spec.ns_to_cycles(spec.t_rtw_ns + spec.t_wtr_ns)
    return turnaround, 0.5 * wr_cyc


def _turnaround_between(spec: MemorySpec, prev_op: str, next_op: str) -> float:
    """Bus-turnaround cycles between two consecutive arbitration grants.

    A grant boundary between engines of the *same* direction costs nothing
    extra (the homogeneous model already prices intra-stream effects).  A
    boundary where the bus direction reverses pays the DRAM turnaround
    segments: tRTW when the earlier grant could end on a read and the
    later one starts with a write, tWTR for the write->read reversal.
    Duplex grants drive both directions, so they pay the reversal on both
    edges against a pure-read or pure-write neighbor and nothing against
    another duplex grant (the per-window duplex turnaround of
    `_direction_overheads` already covers intra-grant reversals).
    """
    if prev_op == next_op:
        return 0.0
    cost = 0.0
    if prev_op in ("read", "duplex") and next_op in ("write", "duplex"):
        cost += spec.ns_to_cycles(spec.t_rtw_ns)
    if prev_op in ("write", "duplex") and next_op in ("read", "duplex"):
        cost += spec.ns_to_cycles(spec.t_wtr_ns)
    return cost


@dataclasses.dataclass
class LatencyTrace:
    """Result of a serial-latency run."""

    cycles: np.ndarray          # per-transaction latency, AXI cycles (float)
    states: list                # per-transaction page state
    refresh_hits: np.ndarray    # bool: transaction stalled behind a refresh

    def ns(self, spec: MemorySpec) -> np.ndarray:
        return self.cycles * spec.cycle_ns


def _expand_addresses(p: RSTParams) -> np.ndarray:
    n = min(p.n, _MAX_EXPAND)
    i = np.arange(n, dtype=np.int64)
    return p.a + (i * p.s) % p.w


@functools.lru_cache(maxsize=32)
def _command_addresses(a: int, s: int, w: int, n: int, b: int,
                       bus_bytes: int) -> Tuple[np.ndarray, int]:
    """Expanded (read-only) column-command address stream + txns used.

    The stream depends only on the RST tuple and the bus width — NOT on the
    address-mapping policy — so one expansion serves every policy of an
    address-mapping sweep at equal (B, S, W).  Arrays are marked read-only;
    `decode` never mutates its input.
    """
    p = RSTParams(n=n, b=b, s=s, w=w, a=a)
    txn_addrs = _expand_addresses(p)
    cmds_per_txn = max(1, b // bus_bytes)
    # Bound total modeled commands: the stream is periodic, so a prefix is
    # representative; without this, multi-MB bursts explode the expansion.
    max_txns = max(16, _MAX_EXPAND // cmds_per_txn)
    if len(txn_addrs) > max_txns:
        txn_addrs = txn_addrs[:max_txns]
    offs = np.arange(cmds_per_txn, dtype=np.int64) * bus_bytes
    addrs = (txn_addrs[:, None] + offs[None, :]).reshape(-1)
    addrs.flags.writeable = False
    return addrs, len(txn_addrs)


def _prev_same_bank(bank: np.ndarray) -> np.ndarray:
    """Index of the previous transaction touching the same bank, -1 if none.

    Stable argsort groups the stream by bank while preserving issue order
    inside each group, so each group's predecessor is one shifted-array
    comparison away.
    """
    n = len(bank)
    prev = np.full(n, -1, dtype=np.int64)
    if n > 1:
        order = np.argsort(bank, kind="stable")
        sorted_bank = bank[order]
        same = sorted_bank[1:] == sorted_bank[:-1]
        prev[order[1:]] = np.where(same, order[:-1], -1)
    return prev


def _contended_latency_delay(base_cycles: np.ndarray, num_engines: int,
                             arbitration: str, burst_beats: int
                             ) -> np.ndarray:
    """Per-transaction queueing-delay addition (cycles) for a serial trace.

    The shift a contended capture list sees (DESIGN.md §9), built from the
    uncontended trace's own service times: under round robin every
    transaction waits out one mean service from each of the other N-1
    engines; under burst grants only each grant-head transaction pays the
    rotation — (N-1)·B·mean — while the B-1 beats riding its grant pay
    zero (same mean as round robin, bimodal distribution); under exclusive
    grants the whole capture rides one grant and the first transaction
    pays the engine-mean whole-stream wait, (N-1)/2 streams.

    The delay is a post-hoc shift on the issue path: the refresh schedule
    stays that of the engine's own service stream (each engine refreshes
    its windows independently of who holds the arbitration grant).
    """
    n = len(base_cycles)
    bb = _grant_beats(arbitration, burst_beats, n)
    delay = np.zeros(n, dtype=np.float64)
    if num_engines <= 1 or n == 0:
        return delay
    if arbitration == "exclusive":
        delay[0] = 0.5 * (num_engines - 1) * float(np.sum(base_cycles))
    else:
        mean_service = float(np.mean(base_cycles))
        delay[::bb] = (num_engines - 1) * bb * mean_service
    return delay


def _contended_latency_delay_mix(base_cycles: np.ndarray, mix: EngineMix,
                                 observed: Tuple[RSTParams, str],
                                 mapping: AddressMapping, spec: MemorySpec, *,
                                 switch_enabled: bool,
                                 switch_extra_cycles: int,
                                 arbitration: str, burst_beats: int
                                 ) -> np.ndarray:
    """Per-transaction queueing-delay addition for a *mixed* serial trace.

    The observed engine is the mix entry equal to ``observed`` (its first
    occurrence fixes the grant position).  Under round-robin/burst grants
    each grant-head transaction waits out one grant from every *other*
    engine — ``bb`` times the sum of their own mean service times, each
    taken from that engine's own uncontended serial trace (per-engine
    service times, not N-1 copies of one shared mean).  Under exclusive
    grants the whole capture rides one grant: the first transaction waits
    out the complete streams of the engines granted *before* it in entry
    order — the mix names the position, so no homogeneous engine-mean
    averaging applies.
    """
    n = len(base_cycles)
    bb = _grant_beats(arbitration, burst_beats, n)
    delay = np.zeros(n, dtype=np.float64)
    if len(mix) <= 1 or n == 0:
        return delay
    k0 = mix.entries.index(observed)
    if arbitration == "exclusive":
        total = 0.0
        for j, (p_j, op_j) in enumerate(mix.entries):
            if j >= k0:
                break
            t = serial_latencies(p_j, mapping, spec, op=op_j,
                                 switch_enabled=switch_enabled,
                                 switch_extra_cycles=switch_extra_cycles)
            total += float(np.sum(t.cycles))
        delay[0] = total
    else:
        total = 0.0
        for j, (p_j, op_j) in enumerate(mix.entries):
            if j == k0:
                continue
            t = serial_latencies(p_j, mapping, spec, op=op_j,
                                 switch_enabled=switch_enabled,
                                 switch_extra_cycles=switch_extra_cycles)
            total += float(np.mean(t.cycles))
        delay[::bb] = bb * total
    return delay


def serial_latencies(
    p: RSTParams,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    op: str = "read",
    switch_enabled: bool = False,
    switch_extra_cycles: int = 0,
    num_engines: int = 1,
    arbitration: str = "round_robin",
    burst_beats: int = 1,
    mix: Optional[EngineMix] = None,
) -> LatencyTrace:
    """Simulate N serial transactions and return per-transaction latencies.

    `op` selects the engine module: ``"read"`` (the paper's measured mode)
    or ``"write"``, where a page miss additionally pays the write-recovery
    segment tWR — the precharge the miss requires must wait out the
    previous write to that bank.  Page-hit and page-closed writes post at
    the read anchors (no precharge on their path).  ``"duplex"`` is
    rejected: serial mode never has a second in-flight transaction to turn
    the bus around for.

    `switch_extra_cycles` is the distance-dependent addition from
    core/switch.py (Table VI); `switch_enabled` alone adds the flat
    7-cycle penalty (paper footnote 9).

    `num_engines` > 1 produces a *contended* trace: the per-engine
    queueing delay of the shared port (DESIGN.md §9) is fed back into the
    per-transaction latencies via `_contended_latency_delay` — every
    transaction under round robin, grant heads only under burst grants (a
    bimodal distribution the contended classifier in core/latency.py
    separates), one up-front stream wait under exclusive grants.  Page
    states and refresh bookkeeping are those of the engine's own stream;
    ``num_engines=1`` is bit-identical to the uncontended trace under
    every policy.

    `mix` names a heterogeneous set of co-resident engines
    (DESIGN.md §13): ``(p, op)`` selects the *observed* engine and must
    be one of the mix entries; the queueing delay fed back into the
    trace sums the *other* entries' own per-engine service times
    (`_contended_latency_delay_mix`) instead of N-1 copies of one shared
    mean.  Every mix op must be serial-capable (read/write — duplex has
    no serial meaning), and a uniform mix normalizes to the homogeneous
    ``num_engines=len(mix)`` path bit-identically.

    Vectorized over refresh epochs: between two refreshes no bank is ever
    closed by the controller, so the page state of every transaction in the
    epoch is a pure function of its previous same-bank access — closed if
    that access predates the epoch (the refresh closed the bank), otherwise
    hit/miss by row comparison.  Each outer iteration therefore commits one
    whole epoch (~tREFI / page-hit-latency transactions) at once.
    """
    if op not in SERIAL_OPS:
        raise ValueError(
            f"serial latency measures one outstanding transaction; op must "
            f"be one of {SERIAL_OPS}, got {op!r}")
    if mix is not None:
        for _, op_k in mix.entries:
            if op_k not in SERIAL_OPS:
                raise ValueError(
                    f"serial latency measures one outstanding transaction; "
                    f"every mix op must be one of {SERIAL_OPS}, got {op_k!r}")
        if (p, op) not in mix.entries:
            raise ValueError(
                "serial_latencies(mix=...) observes the engine named by "
                "(p, op); that (params, op) pair must be one of the mix "
                "entries")
        num_engines = len(mix)
        if mix.uniform_entry() is not None:
            mix = None          # a uniform mix IS the homogeneous request
    if num_engines < 1:
        raise ValueError(f"num_engines must be >= 1, got {num_engines}")
    _grant_beats(arbitration, burst_beats, 1)   # validate the pair eagerly
    p.validate(spec)
    addrs = _expand_addresses(p)
    dec = mapping.decode(addrs)
    bank = np.asarray(mapping.bank_id_from(dec))
    row = np.asarray(dec["R"])
    n = len(addrs)

    base_extra = (spec.switch_penalty if switch_enabled else 0) + (
        switch_extra_cycles if switch_enabled else 0)

    prev_idx = _prev_same_bank(bank)
    rowmatch = np.zeros(n, dtype=bool)
    has_prev = np.nonzero(prev_idx >= 0)[0]
    rowmatch[has_prev] = row[has_prev] == row[prev_idx[has_prev]]

    # Write misses carry the write-recovery segment; hit/closed do not
    # precharge, so the read anchors apply unchanged (DESIGN.md §7).
    wr_extra = spec.ns_to_cycles(spec.t_wr_ns) if op == "write" else 0.0
    c_hit = float(spec.lat_page_hit + base_extra)
    c_closed = float(spec.lat_page_closed + base_extra)
    c_miss = float(spec.lat_page_miss + base_extra) + wr_extra
    # No epoch can span more transactions than tREFI / min-latency; slicing
    # to this cap keeps total work O(N) instead of O(N * epochs).
    epoch_cap = int(spec.t_refi_ns / spec.cycles_to_ns(spec.lat_page_hit)) + 2

    lat = np.zeros(n, dtype=np.float64)
    codes = np.zeros(n, dtype=np.int8)        # 0=hit, 1=closed, 2=miss
    refresh_hits = np.zeros(n, dtype=bool)

    pos = 0
    now_ns = 0.0
    next_refresh = spec.t_refi_ns
    while pos < n:
        # Refresh closes all banks; a transaction arriving during the
        # refresh cycle stalls until it completes (Sec. V-A).
        stall_ns = 0.0
        while now_ns >= next_refresh:
            refresh_end = next_refresh + spec.t_rfc_ns
            if now_ns < refresh_end:
                stall_ns = refresh_end - now_ns
                refresh_hits[pos] = True
            next_refresh += spec.t_refi_ns

        cap = epoch_cap
        while True:
            end = min(n, pos + cap)
            # Closed iff first same-bank access since the epoch's refresh.
            closed = prev_idx[pos:end] < pos
            cyc = np.where(closed, c_closed,
                           np.where(rowmatch[pos:end], c_hit, c_miss))
            cyc[0] += spec.ns_to_cycles(stall_ns)
            # Seeding the cumsum with now_ns reproduces the reference's
            # sequential `now += cycles_to_ns(c)` fold bit-for-bit — epoch
            # boundaries regularly land exactly on a refresh instant (all
            # times are integer cycles), so the >= below is rounding-critical.
            starts = np.cumsum(
                np.concatenate(([now_ns], cyc[:-1] * spec.cycle_ns)))
            crossed = np.nonzero(starts >= next_refresh)[0]
            if crossed.size or end == n:
                break
            cap *= 2  # stall pushed the epoch past the cap; widen and retry

        k = int(crossed[0]) if crossed.size else end - pos
        sl = slice(pos, pos + k)
        lat[sl] = cyc[:k]
        codes[sl] = np.where(closed[:k], 1, np.where(rowmatch[sl], 0, 2))
        if crossed.size:
            now_ns = float(starts[k])   # txn pos+k re-enters the refresh check
        pos += k

    if mix is not None:
        lat = lat + _contended_latency_delay_mix(
            lat, mix, (p, op), mapping, spec,
            switch_enabled=switch_enabled,
            switch_extra_cycles=switch_extra_cycles,
            arbitration=arbitration, burst_beats=burst_beats)
    elif num_engines > 1:
        lat = lat + _contended_latency_delay(lat, num_engines, arbitration,
                                             burst_beats)
    return LatencyTrace(cycles=lat, states=_STATE_NAMES[codes].tolist(),
                        refresh_hits=refresh_hits)


def serial_read_latencies(
    p: RSTParams,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    switch_enabled: bool = False,
    switch_extra_cycles: int = 0,
) -> LatencyTrace:
    """Read-module alias of :func:`serial_latencies` (the paper's measured
    latency mode)."""
    return serial_latencies(p, mapping, spec, op="read",
                            switch_enabled=switch_enabled,
                            switch_extra_cycles=switch_extra_cycles)


@dataclasses.dataclass(frozen=True)
class ThroughputResult:
    gbps: float
    bound: str                    # "bus/ccd" | "bank" | "faw"
    detail: Dict[str, float]

    def __repr__(self):
        return f"ThroughputResult({self.gbps:.2f} GB/s, bound={self.bound})"


def throughput(
    p: RSTParams,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    op: str = "read",
) -> ThroughputResult:
    """Steady-state achievable throughput of one engine on one channel.

    `op` is the traffic direction: ``"read"``, ``"write"``, or ``"duplex"``
    (the read and write modules running concurrently, Sec. III-C-1).  The
    command-issue machinery is shared — the write module saturates WA/WD
    the same way the read module saturates RA — but writes extend each row
    activation by the write recovery tWR, and duplex traffic additionally
    pays the read<->write bus turnaround (tRTW + tWTR) once per reorder
    window.  Sequential (bus-bound) streams therefore measure direction-
    symmetric while activation-heavy streams lose bandwidth on the write
    path, matching the write results of Choi et al. 2020 / Li et al. 2020.
    """
    turnaround_cyc, act_extra_cyc = _direction_overheads(spec, op)
    p.validate(spec)
    cmds_per_txn = max(1, p.b // spec.bus_bytes_per_cycle)
    # Expand bursts into column commands: a B-byte burst is B/bus_bytes
    # commands at consecutive bus-width offsets.  This matters: under the
    # default RGBCG policy the LSB mapped bit is a bank-group bit, so the
    # commands *within* one 64-byte burst already alternate bank groups —
    # the very reason the default policy sustains wire rate (Sec. V-D).
    # The stream is policy-independent, so the cached expansion is shared
    # across every mapping policy probed at equal (B, S, W) — the dominant
    # pattern of the fig6_address_mapping experiment.
    addrs, txns_used = _command_addresses(
        p.a, p.s, p.w, min(p.n, _MAX_EXPAND), p.b, spec.bus_bytes_per_cycle)
    n = len(addrs)
    dec = mapping.decode(addrs)
    bank = np.asarray(mapping.bank_id_from(dec))
    row = np.asarray(dec["R"])
    bg = np.asarray(dec["BG"])

    bounds, total_acts = _stream_bounds(spec, bank, row, bg,
                                        turnaround_cyc, act_extra_cyc)
    bound_name = max(bounds, key=bounds.get)
    steady_cycles = bounds[bound_name]

    eff = (1.0 - spec.t_rfc_ns / spec.t_refi_ns) * (1.0 - spec.sched_overhead)
    total_bytes = txns_used * p.b
    seconds = spec.cycles_to_ns(steady_cycles) * 1e-9
    gbps = total_bytes / seconds / 1e9 * eff if seconds > 0 else 0.0
    # A channel can never beat its wire rate.
    gbps = min(gbps, spec.peak_channel_gbps)

    return ThroughputResult(
        gbps=gbps,
        bound=bound_name,
        detail={**bounds, "txns": float(n), "cmds_per_txn": float(cmds_per_txn),
                "total_acts": float(total_acts), "efficiency": eff},
    )


def _stream_bounds(spec: MemorySpec, bank: np.ndarray, row: np.ndarray,
                   bg: np.ndarray, turnaround_cyc: float,
                   act_extra_cyc: float) -> Tuple[Dict[str, float], int]:
    """The three resource bounds of one decoded column-command stream.

    Shared by :func:`throughput` (one engine's stream) and
    :func:`contended_throughput` (N engines' streams round-robin
    multiplexed onto one shared port) — the scheduler model does not care
    who issued a command, only what it touches.  Returns
    ``({"bus/ccd", "bank", "faw"} -> cycles, total_activations)``.
    """
    n = len(bank)
    ccd_l_cyc = spec.ns_to_cycles(spec.t_ccd_l_ns)
    win = _REORDER_WINDOW
    nw_full, rem = divmod(n, win)

    # --- command-issue bound (data bus + bank-group tCCD_L) ----------------
    # Within a reorder-window chunk the scheduler interleaves commands from G
    # distinct bank groups, so the aggregate command rate is
    # min(1 cmd/cycle, G / tCCD_L).  Interleaving across bank-group *runs* is
    # only possible while two runs coexist in the reorder window, so G is
    # capped by window / (2 * mean run length): long single-BG runs (paper
    # Fig. 6b, RBC with small S) serialize at tCCD_L even though the full
    # stream eventually touches every group.  The per-window distinct-group
    # count is a segment reduction: sort each window row, count transitions.
    transitions = int(np.count_nonzero(bg[1:] != bg[:-1]))
    run_len = n / (transitions + 1)
    g_cap = max(1.0, _REORDER_WINDOW / (2.0 * run_len))
    issue_cycles = 0.0
    if nw_full:
        srt = np.sort(bg[:nw_full * win].reshape(nw_full, win), axis=1)
        uniq = 1 + np.count_nonzero(srt[:, 1:] != srt[:, :-1], axis=1)
        g = np.minimum(uniq.astype(np.float64), g_cap)
        issue_cycles += float(np.sum(win / np.minimum(1.0, g / ccd_l_cyc)))
    if rem:
        g = min(float(len(np.unique(bg[nw_full * win:]))), g_cap)
        issue_cycles += rem / min(1.0, g / ccd_l_cyc)
    # Duplex: one read->write plus one write->read turnaround per window.
    nw_total = nw_full + (1 if rem else 0)
    issue_cycles += turnaround_cyc * nw_total

    # --- bank bound (row activations serialize at tRC per bank) ------------
    # An activation happens whenever a bank is accessed with a different row
    # than its currently open one — i.e. whenever the previous same-bank
    # command (shifted-array comparison over the bank-grouped stream) used a
    # different row, or there is none.  Activations to *different* banks
    # overlap only while both live in the reorder window, so the bound is
    # computed per window: sum over windows of (max activations to any one
    # bank in that window) * tRC.  A stream that rotates banks slowly (runs
    # longer than the window) therefore serializes fully, as the real
    # controller does.  Per-(window, bank) counts come from one bincount.
    prev_idx = _prev_same_bank(bank)
    act = prev_idx < 0
    has_prev = np.nonzero(~act)[0]
    act[has_prev] = row[has_prev] != row[prev_idx[has_prev]]
    total_acts = int(np.count_nonzero(act))
    t_rc_cyc = spec.ns_to_cycles(spec.t_rc_ns)
    bank_cycles = 0.0
    if total_acts:
        act_idx = np.nonzero(act)[0]
        key = (act_idx // win) * spec.num_banks + bank[act_idx]
        counts = np.bincount(key, minlength=nw_total * spec.num_banks)
        per_window_max = counts.reshape(nw_total, spec.num_banks).max(axis=1)
        # Writes hold the row open tWR longer before the next activation's
        # precharge may start (duplex: half the activations are writes).
        bank_cycles = float(per_window_max.sum()) * (t_rc_cyc + act_extra_cyc)

    # --- four-activate-window bound ----------------------------------------
    faw_cycles = total_acts * spec.ns_to_cycles(spec.t_faw_ns) / 4.0

    bounds = {"bus/ccd": issue_cycles, "bank": bank_cycles, "faw": faw_cycles}
    return bounds, total_acts


# ---------------------------------------------------------------------------
# Multi-engine contention (N engines sharing one channel / mini-switch port)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContentionResult:
    """N engines' streams multiplexed onto one shared channel port.

    `aggregate_gbps` is the shared port's total; `queueing_delay_cycles`
    is the *mean* arbitration wait one transaction spends behind the other
    N-1 engines (per-beat wait under round robin; the same mean
    concentrated onto grant heads under burst grants — the head's wait is
    `detail["grant_head_wait_cycles"]`; half the whole-stream rotation
    under exclusive grants).  `arbitration`/`burst_beats` record the
    granularity the result was computed under; `placement` records which
    fabric path the engines shared (``same_channel`` here — the
    cross-channel placements are built by `Engine.evaluate_contention`).

    `mix` records the heterogeneous engine mix the result was computed
    for, or ``None`` for the homogeneous N-identical-engines case — a
    uniform :class:`EngineMix` normalizes to ``None`` (DESIGN.md §13), so
    both spellings of the same workload produce equal results.
    """

    num_engines: int
    aggregate_gbps: float
    bound: str          # "bus/ccd" | "bank" | "faw" | "switch" | "lateral"
    queueing_delay_cycles: float
    detail: Dict[str, float]
    arbitration: str = "round_robin"
    burst_beats: int = 1
    placement: str = "same_channel"
    mix: Optional[EngineMix] = None

    @property
    def per_engine_gbps(self) -> float:
        """Bandwidth-share of one engine (fair round-robin arbitration)."""
        return self.aggregate_gbps / self.num_engines

    def __repr__(self):
        return (f"ContentionResult(N={self.num_engines}, "
                f"{self.aggregate_gbps:.2f} GB/s aggregate, "
                f"bound={self.bound}, arbitration={self.arbitration})")


def _contended_command_addresses(p: RSTParams, bus_bytes: int,
                                 num_engines: int, *,
                                 arbitration: str = "round_robin",
                                 burst_beats: int = 1
                                 ) -> Tuple[np.ndarray, int]:
    """Grant-interleaved column-command stream of N identical engines.

    Engine k traverses its own W-byte window at base ``A + k*W`` (disjoint
    windows, the Choi et al. 2020 multi-PE layout), and the shared port
    rotates grants of `_grant_beats` consecutive transactions per engine:
    one beat under round robin (t0e0, t0e1, ..., t1e0), `burst_beats`
    under burst grants (t0e0..t{B-1}e0, t0e1..), the whole stream under
    exclusive grants (engine-major).  A trailing partial grant round
    rotates the remainder the same way.  The total modeled command budget
    is the single-engine `_MAX_EXPAND` cap, split across engines, so
    contention analyses cost the same as single-engine ones.  For
    ``num_engines == 1`` every policy reduces exactly to
    `_command_addresses` — the read path is bit-identical.
    """
    txn = _expand_addresses(p)
    cmds_per_txn = max(1, p.b // bus_bytes)
    max_txns = max(16, (_MAX_EXPAND // cmds_per_txn) // num_engines)
    if len(txn) > max_txns:
        txn = txn[:max_txns]
    bb = _grant_beats(arbitration, burst_beats, len(txn))
    engine_offs = np.arange(num_engines, dtype=np.int64) * p.w
    # Full grant rounds: (round, engine, beat) flatten rotates bb-beat
    # grants across engines; bb=1 degenerates to the row-major (txn,
    # engine) round-robin flatten, element for element.
    nfull = (len(txn) // bb) * bb
    full = txn[:nfull].reshape(-1, bb)
    parts = [(full[:, None, :] + engine_offs[None, :, None]).reshape(-1)]
    if nfull < len(txn):
        rem = txn[nfull:]
        parts.append((engine_offs[:, None] + rem[None, :]).reshape(-1))
    inter = np.concatenate(parts) if len(parts) > 1 else parts[0]
    offs = np.arange(cmds_per_txn, dtype=np.int64) * bus_bytes
    addrs = (inter[:, None] + offs[None, :]).reshape(-1)
    return addrs, len(txn)


def _queueing_terms(arbitration: str, grant_beats: int, num_engines: int,
                    txns_per_engine: int, mean_service: float
                    ) -> Tuple[float, float]:
    """(mean queueing delay, grant-head wait) in cycles for one policy.

    Round robin: every transaction waits out one transaction from each of
    the other N-1 engines.  Burst grants concentrate the rotation onto the
    grant-head transaction — the head waits out the other engines' whole
    grants ((N-1)·B·service) while the B-1 beats riding its grant wait
    zero — so the mean keeps the (N-1)·service form, evaluated at the
    policy's *own* (usually much better) service time, while the
    distribution turns bimodal.  Exclusive grants pay one whole-stream
    rotation up front; engine k waits k streams, so the engine-mean is
    (N-1)/2 streams and the head (the last engine) waits N-1.
    """
    if arbitration == "exclusive":
        stream = txns_per_engine * mean_service
        return 0.5 * (num_engines - 1) * stream, (num_engines - 1) * stream
    head = (num_engines - 1) * grant_beats * mean_service
    return (num_engines - 1) * mean_service, head


def _contended_throughput_uniform(
    p: RSTParams,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    num_engines: int = 1,
    op: str = "read",
    arbitration: str = "round_robin",
    burst_beats: int = 1,
) -> ContentionResult:
    """Steady-state throughput of N *identical* engines on one port.

    The original homogeneous contention model, preserved verbatim so the
    uniform branch of :func:`contended_throughput_mix` — and therefore
    the :func:`contended_throughput` thin wrapper — stays bit-identical
    to the pre-mix path.

    Models the scenario family of Choi et al. 2020 / Zohouri & Matsuoka
    2019: several compute engines (PEs) multiplexed onto one HBM
    pseudo-channel through the mini-switch.  Each engine issues the same
    RST stream over its own W-byte window (base ``A + k*W``); the shared
    port rotates arbitration grants across engines, and the interleaved
    stream runs through the same three resource bounds as a single
    engine's (`_stream_bounds`) — interleaving is what creates the
    contention: engines share banks but occupy different rows, so row
    locality that survives one engine's stride is destroyed by its
    neighbors' interleaved activations, while short bank-group runs can
    actually *improve* bus utilization (the same effect as Fig. 6's
    policy interleaving).

    `arbitration` is the granularity of that rotation (DESIGN.md §9):

    * ``"round_robin"`` — one transaction per engine per round, the
      worst case (every beat lands between two other engines' row
      activations) and the policy PR 4 shipped;
    * ``"burst"`` — ``burst_beats`` consecutive transactions per grant,
      so row-buffer locality survives *inside* a grant and only the
      grant boundaries thrash — the knob real AXI interconnects expose;
    * ``"exclusive"`` — each engine's whole stream runs to completion,
      the serialized bound ``burst`` converges to as the grant grows
      (``burst_beats >= txns`` is bit-identical to it).

    Two sharing terms come out:

    * **bandwidth sharing** — ``aggregate_gbps`` is clamped at the shared
      port's wire rate; ``per_engine_gbps = aggregate / N`` under fair
      arbitration.
    * **queueing delay** — the mean arbitration wait of one transaction
      (see `_queueing_terms`), plus the grant-head wait in
      ``detail["grant_head_wait_cycles"]``: burst grants keep the mean of
      round robin but concentrate it onto grant heads.

    For ``num_engines == 1`` the result is bit-identical to
    :func:`throughput` (same stream, same bounds, same float ops) with a
    zero queueing term under every policy — pinned by the N=1 parity
    tests; ``arbitration="round_robin"`` is bit-identical to the
    pre-arbitration (PR 4) contended path.
    """
    if num_engines < 1:
        raise ValueError(f"num_engines must be >= 1, got {num_engines}")
    turnaround_cyc, act_extra_cyc = _direction_overheads(spec, op)
    p.validate(spec)
    cmds_per_txn = max(1, p.b // spec.bus_bytes_per_cycle)
    addrs, txns_per_engine = _contended_command_addresses(
        p, spec.bus_bytes_per_cycle, num_engines,
        arbitration=arbitration, burst_beats=burst_beats)
    bb = _grant_beats(arbitration, burst_beats, txns_per_engine)
    dec = mapping.decode(addrs)
    bank = np.asarray(mapping.bank_id_from(dec))
    row = np.asarray(dec["R"])
    bg = np.asarray(dec["BG"])

    bounds, total_acts = _stream_bounds(spec, bank, row, bg,
                                        turnaround_cyc, act_extra_cyc)
    bound_name = max(bounds, key=bounds.get)
    steady_cycles = bounds[bound_name]

    eff = (1.0 - spec.t_rfc_ns / spec.t_refi_ns) * (1.0 - spec.sched_overhead)
    total_txns = txns_per_engine * num_engines
    total_bytes = total_txns * p.b
    seconds = spec.cycles_to_ns(steady_cycles) * 1e-9
    gbps = total_bytes / seconds / 1e9 * eff if seconds > 0 else 0.0
    # The *shared port* can never beat its wire rate.
    gbps = min(gbps, spec.peak_channel_gbps)

    mean_service = steady_cycles / total_txns if total_txns else 0.0
    queueing, head_wait = _queueing_terms(
        arbitration, bb, num_engines, txns_per_engine, mean_service)

    return ContentionResult(
        num_engines=num_engines,
        aggregate_gbps=gbps,
        bound=bound_name,
        queueing_delay_cycles=queueing,
        detail={**bounds, "txns": float(len(bank)),
                "cmds_per_txn": float(cmds_per_txn),
                "txns_per_engine": float(txns_per_engine),
                "total_acts": float(total_acts),
                "mean_service_cycles": mean_service,
                "grant_head_wait_cycles": head_wait,
                "grant_beats": float(bb),
                "efficiency": eff},
        arbitration=arbitration,
        burst_beats=burst_beats,
    )


def contended_throughput(
    p: RSTParams,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    num_engines: int = 1,
    op: str = "read",
    arbitration: str = "round_robin",
    burst_beats: int = 1,
) -> ContentionResult:
    """Steady-state throughput of N *identical* engines sharing one port.

    The homogeneous spelling of :func:`contended_throughput_mix` — a
    thin wrapper building ``EngineMix.uniform(p, op, num_engines)`` and
    delegating, so the old ``num_engines: int`` contract and an
    all-identical mix are the *same request* by construction (DESIGN.md
    §13) and stay bit-identical under every arbitration policy.  The
    model itself (grant interleaving, the three resource bounds, the
    per-policy queueing terms) is documented on
    :func:`_contended_throughput_uniform`, whose result this returns
    unchanged; ``num_engines == 1`` stays bit-identical to
    :func:`throughput` with a zero queueing term under every policy.
    """
    if num_engines < 1:
        raise ValueError(f"num_engines must be >= 1, got {num_engines}")
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; valid: {OPS}")
    return contended_throughput_mix(
        EngineMix.uniform(p, op, num_engines), mapping, spec,
        arbitration=arbitration, burst_beats=burst_beats)


def contended_throughput_mix(
    mix: EngineMix,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    arbitration: str = "round_robin",
    burst_beats: int = 1,
) -> ContentionResult:
    """Steady-state throughput of a heterogeneous engine mix on one port.

    The general contention entry point (DESIGN.md §13): `mix` is an
    ordered tuple of per-engine ``(params, op)`` entries — readers,
    writers, and duplex streams with their own RST tuples — multiplexed
    onto one shared channel port in entry (grant) order.  This is the
    workload regime of Choi et al. 2020 (mixed-direction multi-PE
    designs swinging 30%→90% of nominal) that the homogeneous
    N-identical-engines model cannot name.

    A *uniform* mix (every entry identical) normalizes to the
    homogeneous path and returns its result bit-identically, with
    ``mix=None`` on the result — ``contended_throughput(num_engines=N)``
    and ``EngineMix.uniform(p, op, N)`` are indistinguishable down to
    the float ops and the memo keys built from them.  A genuinely mixed
    mix runs the grant-interleaved per-command model
    (:func:`_contended_throughput_mixed`): per-engine service times,
    per-command direction overheads, and op-aware bus-reversal segments
    at grant boundaries between engines of different directions.  The
    loop oracle `_timing_reference.contended_throughput_mix` pins every
    float of the mixed path at 1e-9.
    """
    uni = mix.uniform_entry()
    if uni is not None:
        return _contended_throughput_uniform(
            uni[0], mapping, spec, num_engines=len(mix), op=uni[1],
            arbitration=arbitration, burst_beats=burst_beats)
    return _contended_throughput_mixed(
        mix, mapping, spec, arbitration=arbitration, burst_beats=burst_beats)


def _mixed_grant_schedule(counts: List[int], bb: int, arbitration: str
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(engine, txn-within-engine, grant-engine-sequence) of a mixed rotation.

    Grant order is entry order.  Round-robin/burst rotate grants of at
    most ``bb`` transactions across the engines that still have
    transactions left (an exhausted engine drops out of the rotation, as
    a real arbiter's request lines deassert); exclusive concatenates
    whole streams engine-major.  For equal counts this reproduces the
    homogeneous `_contended_command_addresses` order element for element:
    full ``bb``-beat rounds, then the engine-major remainder.
    """
    n_eng = len(counts)
    if arbitration == "exclusive":
        order_eng = np.repeat(np.arange(n_eng, dtype=np.int64),
                              np.asarray(counts, dtype=np.int64))
        order_txn = np.concatenate(
            [np.arange(c, dtype=np.int64) for c in counts])
        grants = np.array([k for k in range(n_eng) if counts[k] > 0],
                          dtype=np.int64)
        return order_eng, order_txn, grants
    eng_l: List[int] = []
    txn_l: List[int] = []
    grant_l: List[int] = []
    pos = [0] * n_eng
    active = True
    while active:
        active = False
        for k in range(n_eng):
            take = min(bb, counts[k] - pos[k])
            if take <= 0:
                continue
            active = True
            eng_l.extend([k] * take)
            txn_l.extend(range(pos[k], pos[k] + take))
            grant_l.append(k)
            pos[k] += take
    return (np.asarray(eng_l, dtype=np.int64),
            np.asarray(txn_l, dtype=np.int64),
            np.asarray(grant_l, dtype=np.int64))


def _stream_bounds_mixed(spec: MemorySpec, bank: np.ndarray, row: np.ndarray,
                         bg: np.ndarray, turn_cmd: np.ndarray,
                         extra_cmd: np.ndarray, op_switch_cycles: float
                         ) -> Tuple[Dict[str, float], int]:
    """Per-command generalization of `_stream_bounds` for mixed streams.

    Same three bounds, but the direction overheads are per-*command*
    arrays (each command carries its issuing engine's op): each reorder
    window pays the window-*mean* duplex turnaround, each row activation
    extends tRC by the activating engine's own write-recovery term
    (weighted bincount instead of count * constant), and the issue bound
    carries the grant-boundary bus-reversal segments accumulated by the
    caller.  With uniform per-command arrays every term reduces to the
    homogeneous formula (the mean is the constant; the weighted per-bank
    max is the count max times the constant weight).
    """
    n = len(bank)
    ccd_l_cyc = spec.ns_to_cycles(spec.t_ccd_l_ns)
    win = _REORDER_WINDOW
    nw_full, rem = divmod(n, win)

    transitions = int(np.count_nonzero(bg[1:] != bg[:-1]))
    run_len = n / (transitions + 1)
    g_cap = max(1.0, _REORDER_WINDOW / (2.0 * run_len))
    issue_cycles = 0.0
    if nw_full:
        srt = np.sort(bg[:nw_full * win].reshape(nw_full, win), axis=1)
        uniq = 1 + np.count_nonzero(srt[:, 1:] != srt[:, :-1], axis=1)
        g = np.minimum(uniq.astype(np.float64), g_cap)
        issue_cycles += float(np.sum(win / np.minimum(1.0, g / ccd_l_cyc)))
        issue_cycles += float(np.sum(
            turn_cmd[:nw_full * win].reshape(nw_full, win).mean(axis=1)))
    if rem:
        g = min(float(len(np.unique(bg[nw_full * win:]))), g_cap)
        issue_cycles += rem / min(1.0, g / ccd_l_cyc)
        issue_cycles += float(np.mean(turn_cmd[nw_full * win:]))
    issue_cycles += op_switch_cycles
    nw_total = nw_full + (1 if rem else 0)

    prev_idx = _prev_same_bank(bank)
    act = prev_idx < 0
    has_prev = np.nonzero(~act)[0]
    act[has_prev] = row[has_prev] != row[prev_idx[has_prev]]
    total_acts = int(np.count_nonzero(act))
    t_rc_cyc = spec.ns_to_cycles(spec.t_rc_ns)
    bank_cycles = 0.0
    if total_acts:
        act_idx = np.nonzero(act)[0]
        key = (act_idx // win) * spec.num_banks + bank[act_idx]
        weights = t_rc_cyc + extra_cmd[act_idx]
        sums = np.bincount(key, weights=weights,
                           minlength=nw_total * spec.num_banks)
        bank_cycles = float(
            sums.reshape(nw_total, spec.num_banks).max(axis=1).sum())

    faw_cycles = total_acts * spec.ns_to_cycles(spec.t_faw_ns) / 4.0
    bounds = {"bus/ccd": issue_cycles, "bank": bank_cycles, "faw": faw_cycles}
    return bounds, total_acts


def _contended_throughput_mixed(
    mix: EngineMix,
    mapping: AddressMapping,
    spec: MemorySpec,
    *,
    arbitration: str = "round_robin",
    burst_beats: int = 1,
) -> ContentionResult:
    """Grant-interleaved contention model of a genuinely mixed engine set.

    Engine k issues its own RST stream over its own disjoint window —
    the window base is offset by ``sum(w_j for j < k)``, the
    heterogeneous analog of the homogeneous ``A + k*W`` layout — and the
    shared port rotates `_grant_beats`-sized grants in entry order
    (`_mixed_grant_schedule`).  The interleaved per-command stream runs
    through the per-command resource bounds (`_stream_bounds_mixed`),
    and every grant boundary between engines of different directions
    pays the bus-reversal segments (`_turnaround_between`).  Queueing
    terms generalize the homogeneous ones engine by engine: a
    transaction's arbitration wait sums the *other* engines' own
    per-grant service times instead of N-1 copies of one shared mean,
    and the steady-state cycles split across engines in proportion to
    their command-stream share.
    """
    mix.validate(spec)
    n_eng = len(mix)
    bus = spec.bus_bytes_per_cycle
    over = [_direction_overheads(spec, op_k) for _, op_k in mix.entries]
    turn_e = np.array([t for t, _ in over], dtype=np.float64)
    extra_e = np.array([x for _, x in over], dtype=np.float64)
    cmds_e = np.array([max(1, p_k.b // bus) for p_k, _ in mix.entries],
                      dtype=np.int64)
    # Shared command budget: the single-engine _MAX_EXPAND cap split
    # across engines at the widest per-transaction command count,
    # mirroring the homogeneous budget rule.
    max_txns = max(16, (_MAX_EXPAND // int(cmds_e.max())) // n_eng)
    streams = []
    for p_k, _ in mix.entries:
        t = _expand_addresses(p_k)
        streams.append(t[:max_txns] if len(t) > max_txns else t)
    counts = [len(t) for t in streams]
    bb = _grant_beats(arbitration, burst_beats, max(counts))
    order_eng, order_txn, grants = _mixed_grant_schedule(
        counts, bb, arbitration)

    # Absolute per-transaction addresses: engine k's own stream (which
    # already carries its A) plus its cumulative window offset, gathered
    # in grant order.
    w_offs = np.concatenate(([0], np.cumsum(
        np.array([p_k.w for p_k, _ in mix.entries], dtype=np.int64))))[:-1]
    flat = np.concatenate([streams[k] + w_offs[k] for k in range(n_eng)])
    starts = np.concatenate(
        ([0], np.cumsum(np.asarray(counts, dtype=np.int64))))[:-1]
    txn_addr = flat[starts[order_eng] + order_txn]

    # Ragged command expansion: each transaction carries its own engine's
    # B/bus_bytes column commands at consecutive bus-width offsets.
    slot_cmds = cmds_e[order_eng]
    total_cmds = int(slot_cmds.sum())
    slot_of = np.repeat(np.arange(len(order_eng), dtype=np.int64), slot_cmds)
    first_cmd = np.cumsum(slot_cmds) - slot_cmds
    within = np.arange(total_cmds, dtype=np.int64) - first_cmd[slot_of]
    addrs = txn_addr[slot_of] + within * bus
    eng_cmd = order_eng[slot_of]

    dec = mapping.decode(addrs)
    bank = np.asarray(mapping.bank_id_from(dec))
    row = np.asarray(dec["R"])
    bg = np.asarray(dec["BG"])

    # Bus-reversal segments at grant boundaries between different ops:
    # an (engine, engine) cost table gathered along the grant sequence.
    pair_cost = np.array(
        [[_turnaround_between(spec, oi, oj) for oj in mix.ops]
         for oi in mix.ops], dtype=np.float64)
    op_switch = (float(pair_cost[grants[:-1], grants[1:]].sum())
                 if len(grants) > 1 else 0.0)

    bounds, total_acts = _stream_bounds_mixed(
        spec, bank, row, bg, turn_e[eng_cmd], extra_e[eng_cmd], op_switch)
    bound_name = max(bounds, key=bounds.get)
    steady_cycles = bounds[bound_name]

    eff = (1.0 - spec.t_rfc_ns / spec.t_refi_ns) * (1.0 - spec.sched_overhead)
    total_txns = int(sum(counts))
    total_bytes = int(sum(
        c * p_k.b for c, (p_k, _) in zip(counts, mix.entries)))
    seconds = spec.cycles_to_ns(steady_cycles) * 1e-9
    gbps = total_bytes / seconds / 1e9 * eff if seconds > 0 else 0.0
    # The *shared port* can never beat its wire rate.
    gbps = min(gbps, spec.peak_channel_gbps)

    mean_service = steady_cycles / total_txns if total_txns else 0.0
    # Per-engine per-transaction service: the steady-state cycles split
    # in proportion to each engine's share of the command stream.
    mean_e = (steady_cycles * cmds_e.astype(np.float64) / total_cmds
              if total_cmds else np.zeros(n_eng, dtype=np.float64))
    counts_f = np.asarray(counts, dtype=np.float64)
    if arbitration == "exclusive":
        stream_e = counts_f * mean_e
        waits = np.concatenate(([0.0], np.cumsum(stream_e)[:-1]))
        queueing = float(np.mean(waits))
        head_wait = float(waits[-1])
    else:
        rot_e = float(mean_e.sum()) - mean_e   # sum_{j != k} mean_j
        queueing = float(np.mean(rot_e))
        head_wait = float(bb * rot_e.max())

    return ContentionResult(
        num_engines=n_eng,
        aggregate_gbps=gbps,
        bound=bound_name,
        queueing_delay_cycles=queueing,
        detail={**bounds, "txns": float(len(bank)),
                "cmds_per_txn": total_cmds / total_txns if total_txns else 0.0,
                "txns_per_engine": total_txns / n_eng,
                "total_acts": float(total_acts),
                "mean_service_cycles": mean_service,
                "grant_head_wait_cycles": head_wait,
                "grant_beats": float(bb),
                "op_switch_cycles": op_switch,
                "mix_size": float(n_eng),
                "efficiency": eff},
        arbitration=arbitration,
        burst_beats=burst_beats,
        mix=mix,
    )


def refresh_interval_estimate(trace: LatencyTrace, spec: MemorySpec) -> float:
    """Estimate tREFI (ns) from latency spikes, as the paper does in V-A."""
    lat = trace.cycles
    thresh = np.median(lat) + 10.0
    spike_idx = np.nonzero(lat > thresh)[0]
    if len(spike_idx) < 2:
        return math.nan
    # Time of each spike = cumulative latency up to it.
    t = np.cumsum(spec.cycles_to_ns(lat))
    spike_times = t[spike_idx]
    return float(np.mean(np.diff(spike_times)))
