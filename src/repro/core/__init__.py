"""Shuhai core: the paper's contribution as a composable library.

Public surface:
  RSTParams, EngineRegisters        — runtime parameters (Table I) + packing
  addresses_np / addresses_jnp      — Eq. 1 address streams
  AddressMapping, get_mapping       — Table II policies
  serial_read_latencies, throughput — the calibrated timing model
  Engine                            — one benchmarking engine per channel
  ShuhaiCampaign                    — host-side suites (one per table/figure)
  Sweep                             — batch-first campaign grids (memoized)
  SwitchModel, HBMTopology          — Sec. II / VI switch + topology
  MemoryOracle, AccessPattern       — TPU-facing constants + derating
  choose_layout, advise_microbatch  — the technique as a framework feature
"""
from repro.core.address_mapping import AddressMapping, get_mapping, policies_for
from repro.core.autotune import (LayoutCandidate, advise_microbatch,
                                 advise_remat, choose_layout, score_layouts)
from repro.core.bench_host import ShuhaiCampaign, default_campaigns
from repro.core.channels import DDR4Topology, HBMTopology
from repro.core.engine import Engine
from repro.core.hwspec import DDR4, HBM, TPU_V5E, ChipSpec, MemorySpec
from repro.core.latency import LatencyModule
from repro.core.oracle import AccessPattern, MemoryOracle
from repro.core.params import EngineRegisters, RSTParams
from repro.core.rst import addresses_jnp, addresses_np, block_params
from repro.core.sweep import Sweep, SweepPoint, SweepResult
from repro.core.switch import SwitchModel
from repro.core.timing_model import (LatencyTrace, ThroughputResult,
                                     refresh_interval_estimate,
                                     serial_read_latencies, throughput)

__all__ = [
    "AddressMapping", "get_mapping", "policies_for",
    "LayoutCandidate", "advise_microbatch", "advise_remat", "choose_layout",
    "score_layouts", "ShuhaiCampaign", "default_campaigns",
    "DDR4Topology", "HBMTopology", "Engine",
    "DDR4", "HBM", "TPU_V5E", "ChipSpec", "MemorySpec",
    "LatencyModule", "AccessPattern", "MemoryOracle",
    "EngineRegisters", "RSTParams",
    "addresses_jnp", "addresses_np", "block_params",
    "Sweep", "SweepPoint", "SweepResult",
    "SwitchModel", "LatencyTrace", "ThroughputResult",
    "refresh_interval_estimate", "serial_read_latencies", "throughput",
]
