"""Shuhai core: the paper's contribution as a composable library.

Public surface:
  RSTParams, EngineRegisters        — runtime parameters (Table I) + packing
  addresses_np / addresses_jnp      — Eq. 1 address streams
  AddressMapping, get_mapping       — Table II policies (registrable:
                                      register_policies)
  serial_read_latencies, throughput — the calibrated timing model
  contended_throughput              — N engines sharing one channel port
                                      (ARBITRATION_POLICIES grant axis)
  Engine, Backend                   — engines + pluggable measurement
                                      backends (register_backend);
                                      PLACEMENTS routes cross-channel
                                      contention, UnsupportedCapability
                                      marks missing backend abilities
  MemorySpec, register_spec         — registrable memory systems; HBM/DDR4
                                      (measured) + HBM3/DDR3 (modeled)
  Experiment, run_experiment        — declarative paper-artifact registry
                                      (+ write/duplex family, catalog)
  ShuhaiCampaign                    — deprecated suite shims over the registry
  Sweep                             — batch-first campaign grids (memoized)
  SwitchModel, SwitchTopology       — Sec. II / VI switch + parametric
                                      fabrics (register_topology)
  MemoryOracle, AccessPattern       — TPU-facing constants + derating
  choose_layout, advise_microbatch  — the technique as a framework feature
"""
from repro.core.address_mapping import (AddressMapping, get_mapping,
                                        policies_for, register_policies)
from repro.core.autotune import (LayoutCandidate, LayoutConfig, LayoutTuner,
                                 TuneReport, TuneRound, advise_microbatch,
                                 advise_remat, choose_layout, score_layouts,
                                 tune_layout)
from repro.core.bench_host import ShuhaiCampaign, default_campaigns
from repro.core.channels import (CrossingLatencyTable, DDR4Topology,
                                 HBMTopology, SwitchTopology,
                                 available_topologies, flat_topology,
                                 register_topology, topology_for)
from repro.core.engine import (Backend, Engine, UnsupportedCapability,
                               available_backends, get_backend,
                               register_backend)
from repro.core.experiments import (Experiment, all_experiments,
                                    experiments_for, get_experiment,
                                    register_experiment, run_experiment)
from repro.core.hwspec import (DDR3, DDR4, HBM, HBM3, TPU_V5E, ChipSpec,
                               MemorySpec, available_chips, available_specs,
                               chip_by_name, register_chip, register_spec,
                               spec_by_name)
from repro.core.latency import LatencyModule
from repro.core.oracle import AccessPattern, MemoryOracle
from repro.core.params import EngineRegisters, RSTParams
from repro.core.roofline_empirical import (EnvelopePoint, RooflineEnvelope,
                                           build_envelope,
                                           config_ceiling_gbps,
                                           measure_envelope)
from repro.core.rst import addresses_jnp, addresses_np, block_params
from repro.core.sweep import Sweep, SweepPoint, SweepResult
from repro.core.switch import PLACEMENTS, SwitchModel
from repro.core.timing_model import (ARBITRATION_POLICIES, ContentionResult,
                                     LatencyTrace, ThroughputResult,
                                     contended_throughput,
                                     refresh_interval_estimate,
                                     serial_latencies, serial_read_latencies,
                                     throughput)

__all__ = [
    "AddressMapping", "get_mapping", "policies_for", "register_policies",
    "LayoutCandidate", "LayoutConfig", "LayoutTuner", "TuneReport",
    "TuneRound", "advise_microbatch", "advise_remat", "choose_layout",
    "score_layouts", "tune_layout",
    "EnvelopePoint", "RooflineEnvelope", "build_envelope",
    "config_ceiling_gbps", "measure_envelope",
    "ShuhaiCampaign", "default_campaigns",
    "CrossingLatencyTable", "DDR4Topology", "HBMTopology", "SwitchTopology",
    "available_topologies", "flat_topology", "register_topology",
    "topology_for",
    "Backend", "Engine", "UnsupportedCapability", "available_backends",
    "get_backend", "register_backend",
    "Experiment", "all_experiments", "experiments_for", "get_experiment",
    "register_experiment", "run_experiment",
    "DDR3", "DDR4", "HBM", "HBM3", "TPU_V5E", "ChipSpec", "MemorySpec",
    "available_chips", "available_specs", "chip_by_name", "register_chip",
    "register_spec", "spec_by_name",
    "LatencyModule", "AccessPattern", "MemoryOracle",
    "EngineRegisters", "RSTParams",
    "addresses_jnp", "addresses_np", "block_params",
    "Sweep", "SweepPoint", "SweepResult",
    "SwitchModel", "LatencyTrace", "ThroughputResult", "ContentionResult",
    "ARBITRATION_POLICIES", "PLACEMENTS",
    "contended_throughput", "refresh_interval_estimate", "serial_latencies",
    "serial_read_latencies", "throughput",
]
