"""Software component: the host-side campaign driver (paper Sec. III-B).

`ShuhaiCampaign` plays the role of the CPU software talking to the parameter
module over PCIe: it packs runtime registers, fans them out to M engines
(M = 32 for HBM, M = 2 for DDR4, Fig. 3), triggers runs, and collects
status/latency lists.  Every paper table/figure has a `suite_*` method here;
benchmarks/ are thin CSV printers over these.

Since the sweep refactor the multi-point suites are *batch-first*: each one
plans its whole (params × policy × channel) grid as a `core.sweep.Sweep`
and executes it in one `run()`, which memoizes repeated points and
broadcasts channel-independent results (DESIGN.md §4).  Single-point suites
(`suite_refresh`, `suite_idle_latency`) keep the register-faithful
configure-then-trigger flow through one engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.address_mapping import DEFAULT_POLICY, policies_for
from repro.core.channels import AXI_PER_MINI_SWITCH, NUM_AXI_CHANNELS, HBMTopology
from repro.core.engine import Engine
from repro.core.hwspec import DDR4, HBM, MemorySpec
from repro.core.latency import LatencyModule
from repro.core.params import RSTParams
from repro.core.sweep import Sweep
from repro.core.switch import SwitchModel
from repro.core.timing_model import refresh_interval_estimate

MB = 1024**2


@dataclasses.dataclass
class ShuhaiCampaign:
    spec: MemorySpec = HBM
    backend: str = "sim"

    def __post_init__(self):
        m = self.spec.num_channels  # M engines, Fig. 3
        self.engines: List[Engine] = [
            Engine(channel=c, spec=self.spec, backend=self.backend)
            for c in range(m)
        ]

    # ------------------------------------------------------------------ utils
    def _engine(self, ch: int) -> Engine:
        return self.engines[ch]

    def _sweep(self) -> Sweep:
        return Sweep(self.spec, self.backend)

    # --------------------------------------------------------------- Fig. 4
    def suite_refresh(self, n: int = 1024) -> Dict[str, object]:
        """Serial-read latency timeline showing periodic refresh spikes.
        Paper setting: B=32, S=64, W=0x1000000, N=1024 (HBM)."""
        p = RSTParams(n=n, b=self.spec.min_burst, s=64, w=0x1000000)
        eng = self._engine(0)
        eng.configure_read(p)
        trace = eng.read_latency()
        return {
            "latency_cycles": trace.cycles,
            "refresh_hits": trace.refresh_hits,
            "estimated_refresh_interval_ns":
                refresh_interval_estimate(trace, self.spec),
            "params": p,
        }

    # ------------------------------------------------- Fig. 5 / Table IV
    def suite_idle_latency(self) -> Dict[str, Dict[str, float]]:
        """Page hit/closed/miss latencies via the paper's two-stride probe:
        S=128 isolates hit+closed, S=128K forces misses. Switch disabled
        (footnote 6/9)."""
        eng = self._engine(0)
        out: Dict[str, Dict[str, float]] = {}
        module = LatencyModule()

        eng.configure_read(RSTParams(n=1024, b=self.spec.min_burst,
                                     s=128, w=0x1000000))
        cap_small = module.capture(eng.read_latency())
        cats_small = module.category_latencies(cap_small, self.spec)

        eng.configure_read(RSTParams(n=1024, b=self.spec.min_burst,
                                     s=128 * 1024, w=0x1000000))
        cap_large = module.capture(eng.read_latency())
        cats_large = module.category_latencies(cap_large, self.spec)

        for name, cyc in (("page_hit", cats_small["hit"]),
                          ("page_closed", cats_small["closed"]),
                          ("page_miss", cats_large["miss"])):
            out[name] = {"cycles": cyc, "ns": cyc * self.spec.cycle_ns}
        return out

    # --------------------------------------------------------------- Fig. 6
    def suite_address_mapping(
        self,
        strides: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096, 8192,
                                  16384, 32768),
        bursts: Optional[Sequence[int]] = None,
        w: int = 0x10000000,
        n: int = 4096,
    ) -> Dict[str, Dict[int, Dict[int, float]]]:
        """Throughput for every address-mapping policy x stride x burst,
        planned as one batched sweep."""
        bursts = bursts or (self.spec.min_burst, 2 * self.spec.min_burst)
        sweep = self._sweep()
        keys: List[Tuple[str, int, int]] = []
        for policy in policies_for(self.spec):
            for b in bursts:
                for s in strides:
                    if s < b:
                        continue
                    sweep.add(RSTParams(n=n, b=b, s=s, w=w), policy=policy)
                    keys.append((policy, b, s))
        results: Dict[str, Dict[int, Dict[int, float]]] = {
            policy: {b: {} for b in bursts} for policy in policies_for(self.spec)}
        for (policy, b, s), r in zip(keys, sweep.run()):
            results[policy][b][s] = r.value.gbps
        return results

    # --------------------------------------------------------------- Fig. 7
    def suite_locality(
        self,
        strides: Sequence[int] = (64, 256, 1024, 4096, 16384),
        bursts: Optional[Sequence[int]] = None,
        n: int = 4096,
    ) -> Dict[int, Dict[int, Dict[int, float]]]:
        """W=8K (locality) vs W=256M (baseline) throughput (Sec. V-E).

        Combinations with S < B or S > W violate the RST constraints
        (Table I) and are omitted from the result — the returned per-burst
        dict then simply lacks that stride key, so consumers must guard
        lookups (see benchmarks/run.py:bench_fig7_locality).
        """
        bursts = bursts or (self.spec.min_burst, 2 * self.spec.min_burst)
        sweep = self._sweep()
        keys: List[Tuple[int, int, int]] = []
        windows = (8 * 1024, 256 * MB)
        for w in windows:
            for b in bursts:
                for s in strides:
                    if s < b or s > w:
                        continue  # invalid RST point (Table I): skipped
                    sweep.add(RSTParams(n=n, b=b, s=s, w=w))
                    keys.append((w, b, s))
        results: Dict[int, Dict[int, Dict[int, float]]] = {
            w: {b: {} for b in bursts} for w in windows}
        for (w, b, s), r in zip(keys, sweep.run()):
            results[w][b][s] = r.value.gbps
        return results

    # --------------------------------------------------------------- Table V
    def suite_total_throughput(self) -> Dict[str, float]:
        """All M engines hit their local channels simultaneously; per the
        paper (footnote 11) channels are independent, so the aggregate is
        per-channel throughput x M.  The sweep evaluates one channel and
        broadcasts it to the other M-1."""
        p = RSTParams(n=8192, b=self.spec.min_burst, s=self.spec.min_burst,
                      w=0x10000000)
        sweep = self._sweep()
        for eng in self.engines:
            eng.configure_read(p)
            sweep.add(p, channel=eng.channel)
        per_channel = [r.value.gbps for r in sweep.run()]
        if self.backend == "sim":
            # Mirror the read module's completion count, as read_throughput
            # would have (status register, Sec. III-C-3).
            for eng in self.engines:
                eng.registers = dataclasses.replace(eng.registers, status=p.n)
        return {
            "per_channel_gbps": float(np.mean(per_channel)),
            "num_channels": len(self.engines),
            "total_gbps": float(np.sum(per_channel)),
            "theoretical_gbps": self.spec.peak_total_gbps,
        }

    # -------------------------------------------------------------- Table VI
    def suite_switch_latency(self, dst_channel: int = 0
                             ) -> Dict[int, Dict[str, float]]:
        """Idle latency from every AXI channel to one HBM channel, switch ON.

        Batched: all 64 probe runs are planned in one sweep, and the four
        channels of each mini-switch share a switch distance, so only the
        8 distinct (params, extra) latency points are simulated."""
        if self.spec.name != "hbm":
            raise ValueError("the DDR4 controller has no switch (Sec. IV-D)")
        module = LatencyModule()
        p_small = RSTParams(n=1024, b=32, s=128, w=0x1000000)
        p_large = RSTParams(n=1024, b=32, s=128 * 1024, w=0x1000000)
        sweep = self._sweep()
        for ch in range(NUM_AXI_CHANNELS):
            for p in (p_small, p_large):
                sweep.add_latency(p, channel=ch, dst_channel=dst_channel,
                                  switch_enabled=True)
        results = sweep.run()
        out: Dict[int, Dict[str, float]] = {}
        for ch in range(NUM_AXI_CHANNELS):
            eng = self._engine(ch)
            extra = eng.switch.distance_extra_cycles(ch, dst_channel) + \
                self.spec.switch_penalty
            cap_small = module.capture(results[2 * ch].value)
            cats = module.category_latencies(cap_small, self.spec, extra)
            cap_large = module.capture(results[2 * ch + 1].value)
            cats_miss = module.category_latencies(cap_large, self.spec, extra)
            out[ch] = {"hit": cats["hit"], "closed": cats["closed"],
                       "miss": cats_miss["miss"]}
        return out

    # --------------------------------------------------------------- Fig. 8
    def suite_switch_throughput(
        self, dst_channel: int = 0,
        strides: Sequence[int] = (64, 256, 1024, 4096),
    ) -> Dict[int, Dict[int, float]]:
        """Throughput from one AXI channel per mini-switch to HBM channel 0.
        Paper setting: B=64, W=0x1000000, N=200000.  One sweep point per
        stride; the non-blocking switch broadcasts it to all mini-switches."""
        if self.spec.name != "hbm":
            raise ValueError("the DDR4 controller has no switch")
        sweep = self._sweep()
        keys: List[Tuple[int, int]] = []
        for sw in range(NUM_AXI_CHANNELS // AXI_PER_MINI_SWITCH):
            ch = sw * AXI_PER_MINI_SWITCH
            for s in strides:
                sweep.add(RSTParams(n=200000, b=64, s=s, w=0x1000000),
                          channel=ch, dst_channel=dst_channel)
                keys.append((ch, s))
        out: Dict[int, Dict[int, float]] = {}
        for (ch, s), r in zip(keys, sweep.run()):
            out.setdefault(ch, {})[s] = r.value.gbps
        return out


def default_campaigns(backend: str = "sim") -> Dict[str, ShuhaiCampaign]:
    return {"hbm": ShuhaiCampaign(HBM, backend),
            "ddr4": ShuhaiCampaign(DDR4, backend)}
