"""Software component: the host-side campaign driver (paper Sec. III-B).

`ShuhaiCampaign` plays the role of the CPU software talking to the parameter
module over PCIe.  Since the experiment-registry redesign the suites
themselves live in :mod:`repro.core.experiments` — one declarative
:class:`~repro.core.experiments.Experiment` per paper table/figure, lowered
onto a batched :class:`~repro.core.sweep.Sweep` by
:func:`~repro.core.experiments.run_experiment`.

The `suite_*` methods below are **deprecated shims**: each one forwards its
arguments to the registered experiment of the same artifact and returns the
identical result structure.  They are kept so existing callers (and the
paper-era reading order: "every table/figure has a suite_* method") keep
working; new code should call `run_experiment` directly:

    from repro.core.experiments import run_experiment
    run_experiment("fig6_address_mapping", spec=HBM3, backend="sim")

The campaign still owns M engines (M = spec.num_channels, Fig. 3) so the
register-faithful configure-then-trigger flow of the paper remains
demonstrable through `self.engines`.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence

from repro.core.engine import Engine
from repro.core.experiments import run_experiment
from repro.core.hwspec import HBM, MemorySpec, available_specs, spec_by_name


def _deprecated(suite: str, experiment: str) -> None:
    # stacklevel: _deprecated(1) -> _run(2) -> suite_*(3) -> caller(4).
    warnings.warn(
        f"ShuhaiCampaign.{suite} is a deprecated shim; use "
        f"run_experiment({experiment!r}, spec, backend) instead",
        DeprecationWarning, stacklevel=4)


@dataclasses.dataclass
class ShuhaiCampaign:
    spec: MemorySpec = HBM
    backend: str = "sim"

    def __post_init__(self):
        m = self.spec.num_channels  # M engines, Fig. 3
        self.engines: List[Engine] = [
            Engine(channel=c, spec=self.spec, backend=self.backend)
            for c in range(m)
        ]

    # ------------------------------------------------------------------ utils
    def _engine(self, ch: int) -> Engine:
        return self.engines[ch]

    def _run(self, suite: str, experiment: str, **options):
        _deprecated(suite, experiment)
        return run_experiment(experiment, self.spec, self.backend, **options)

    # --------------------------------------------------------------- Fig. 4
    def suite_refresh(self, n: int = 1024) -> Dict[str, object]:
        """Deprecated shim for the ``fig4_refresh`` experiment."""
        return self._run("suite_refresh", "fig4_refresh", n=n)

    # ------------------------------------------------- Fig. 5 / Table IV
    def suite_idle_latency(self) -> Dict[str, Dict[str, float]]:
        """Deprecated shim for the ``table4_idle_latency`` experiment."""
        return self._run("suite_idle_latency", "table4_idle_latency")

    # --------------------------------------------------------------- Fig. 6
    def suite_address_mapping(
        self,
        strides: Optional[Sequence[int]] = None,
        bursts: Optional[Sequence[int]] = None,
        w: Optional[int] = None,
        n: Optional[int] = None,
    ) -> Dict[str, Dict[int, Dict[int, float]]]:
        """Deprecated shim for the ``fig6_address_mapping`` experiment."""
        return self._run("suite_address_mapping", "fig6_address_mapping",
                         strides=strides, bursts=bursts, w=w, n=n)

    # --------------------------------------------------------------- Fig. 7
    def suite_locality(
        self,
        strides: Optional[Sequence[int]] = None,
        bursts: Optional[Sequence[int]] = None,
        n: Optional[int] = None,
    ) -> Dict[int, Dict[int, Dict[int, float]]]:
        """Deprecated shim for the ``fig7_locality`` experiment.

        RST-invalid combinations (S < B or S > W, Table I) are omitted from
        the result, so consumers must guard lookups.
        """
        return self._run("suite_locality", "fig7_locality",
                         strides=strides, bursts=bursts, n=n)

    # --------------------------------------------------------------- Table V
    def suite_total_throughput(self) -> Dict[str, float]:
        """Deprecated shim for the ``table5_total_throughput`` experiment.

        Keeps the paper's register flow observable: every engine's read
        register is configured with the run's params and (on deterministic
        backends) the status register mirrors the completion count, as
        `read_throughput` would have (Sec. III-C-3).
        """
        res = self._run("suite_total_throughput", "table5_total_throughput")
        # The old suite returned numeric entries only; keep that contract
        # and use the grid's params for the register mirror instead.
        p = res.pop("params")
        for eng in self.engines:
            eng.configure_read(p)
            if eng.backend_impl.deterministic:
                eng.registers = dataclasses.replace(eng.registers,
                                                    status=p.n)
        return res

    # -------------------------------------------------------------- Table VI
    def suite_switch_latency(self, dst_channel: int = 0
                             ) -> Dict[int, Dict[str, float]]:
        """Deprecated shim for the ``table6_switch_latency`` experiment."""
        return self._run("suite_switch_latency", "table6_switch_latency",
                         dst_channel=dst_channel)

    # --------------------------------------------------------------- Fig. 8
    def suite_switch_throughput(
        self, dst_channel: int = 0,
        strides: Optional[Sequence[int]] = None,
    ) -> Dict[int, Dict[int, float]]:
        """Deprecated shim for the ``fig8_switch_throughput`` experiment."""
        return self._run("suite_switch_throughput", "fig8_switch_throughput",
                         dst_channel=dst_channel, strides=strides)


def default_campaigns(backend: str = "sim", *,
                      specs: Optional[Sequence[str]] = None
                      ) -> Dict[str, ShuhaiCampaign]:
    """One campaign per memory spec (default: every registered spec)."""
    names = list(specs) if specs else available_specs()
    return {name: ShuhaiCampaign(spec_by_name(name), backend)
            for name in names}
