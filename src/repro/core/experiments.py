"""Declarative experiment registry: each paper table/figure as one spec.

The paper's closing claim is that Shuhai "can be easily generalized to
other FPGA boards or other generations of memory" — this module is that
claim as code.  Every artifact of Sec. V/VI is a single :class:`Experiment`
object: a *plan* that lays an ``(RSTParams × policy × channel × op)`` grid
for any :class:`~repro.core.hwspec.MemorySpec`, and a named *derive* reducer
that turns the evaluated grid back into the table/figure quantities.  One
generic runner, :func:`run_experiment`, lowers any spec onto
:class:`~repro.core.sweep.Sweep` for batched (memoized, channel-broadcast)
execution on any registered backend.

Beyond the paper's read-only artifacts, a write-path family (Sec. IV as
first-class workloads: ``table5_write_throughput``, ``fig7_write_locality``,
``duplex_rw_sweep``) exercises the write and duplex directions of the
timing model / pallas kernels on every registered memory system.

:func:`catalog_markdown` renders the whole registry as the README's
"Experiment catalog" table (``python -m benchmarks.run --catalog``).

The three old entry points are thin views over this registry:
`ShuhaiCampaign.suite_*` (deprecated shims), `benchmarks/run.py` (CSV/JSON
rows via each experiment's `summarize`), and `examples/shuhai_campaign.py`
(flat CSV via each experiment's `flatten`).  None of them contain grid
logic of their own.

Extending the library (DESIGN.md §6):

* new memory generation — ``hwspec.register_spec`` + an
  ``address_mapping.register_policies`` table; every experiment whose
  requirements the spec meets runs unchanged (HBM3/DDR3 ship built in);
* new execution substrate — subclass ``engine.Backend`` and
  ``engine.register_backend`` it;
* new measurement — build an :class:`Experiment` and
  :func:`register_experiment` it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.address_mapping import DEFAULT_POLICY, policies_for
from repro.core.channels import topology_for
from repro.core.engine_mix import EngineMix
from repro.core.hwspec import HBM, MemorySpec
from repro.core.latency import LatencyModule
from repro.core.params import RSTParams
from repro.core.engine import get_backend
from repro.core.sweep import (KIND_CONTENTION, KIND_LATENCY,
                              KIND_THROUGHPUT, Sweep, SweepPoint)
from repro.core.switch import PLACEMENTS, SwitchModel
from repro.core.timing_model import (_contended_latency_delay,
                                     refresh_interval_estimate)

MB = 1024**2

# One planned grid entry: the caller-meaningful key the derive reducer will
# see, plus the sweep point that produces its value.
PlannedPoint = Tuple[Any, SweepPoint]
Plan = Callable[[MemorySpec, Mapping[str, Any]], List[PlannedPoint]]
Derive = Callable[[MemorySpec, List[Tuple[Any, Any]], Mapping[str, Any]], Any]


@dataclasses.dataclass(frozen=True)
class Experiment:
    """One paper table/figure as a declarative spec.

    `plan` builds the keyed grid for a memory spec + options; `derive`
    reduces the keyed sweep values to the artifact's result structure.
    `summarize` renders the one-line headline used by benchmarks/run.py;
    `flatten` renders (key, value) CSV rows for the example driver.
    `defaults` are the canonical paper options; `quick` overlays them for
    fast CI runs; `bench` overlays them for the benchmark harness.
    """

    name: str                       # registry key, e.g. "fig6_address_mapping"
    artifact: str                   # paper reference, e.g. "Fig. 6"
    title: str
    plan: Plan
    derive: Derive
    defaults: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    quick: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    bench: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    requires_switch: bool = False
    summarize: Optional[Callable[[MemorySpec, Any], str]] = None
    flatten: Optional[Callable[[MemorySpec, Any], List[Tuple[str, str]]]] = None
    # Historical benchmark row prefix, where it differs from `name` (keeps
    # BENCH_*.json perf trajectories comparable across the redesign).
    bench_label: Optional[str] = None
    # Spec names benchmarks/run.py times this experiment on.  None keeps
    # the harness default (the paper's measured hbm/ddr4 pair — widening it
    # would rename historical BENCH_*.json rows); the write/duplex family
    # opts into all four registered systems explicitly.
    bench_specs: Optional[Tuple[str, ...]] = None

    def available_on(self, spec: MemorySpec) -> bool:
        return spec.has_switch or not self.requires_switch

    def summary(self, spec: MemorySpec, result: Any) -> str:
        """One-line headline; falls back to a repr for experiments that
        register no `summarize` of their own."""
        if self.summarize is not None:
            return self.summarize(spec, result)
        return repr(result)[:120]

    def rows(self, spec: MemorySpec, result: Any) -> List[Tuple[str, str]]:
        """(key, value) CSV rows; falls back to one repr row for
        experiments that register no `flatten` of their own."""
        if self.flatten is not None:
            return self.flatten(spec, result)
        return [("result", repr(result)[:120])]

    def options(self, *, quick: bool = False, bench: bool = False,
                **overrides) -> Dict[str, Any]:
        """defaults <- bench overlay <- quick overlay <- explicit overrides
        (None-valued overrides fall back to the layered value)."""
        out = dict(self.defaults)
        if bench:
            out.update(self.bench)
        if quick:
            out.update(self.quick)
        out.update({k: v for k, v in overrides.items() if v is not None})
        unknown = set(out) - set(self.defaults)
        if unknown:
            raise TypeError(
                f"{self.name}: unknown option(s) {sorted(unknown)}; "
                f"valid: {sorted(self.defaults)}")
        return out


_EXPERIMENT_REGISTRY: Dict[str, Experiment] = {}


def register_experiment(exp: Experiment, *, override: bool = False
                        ) -> Experiment:
    if exp.name in _EXPERIMENT_REGISTRY and not override:
        raise ValueError(
            f"experiment {exp.name!r} already registered; pass "
            f"override=True to replace it")
    _EXPERIMENT_REGISTRY[exp.name] = exp
    return exp


def get_experiment(name: str) -> Experiment:
    exp = _EXPERIMENT_REGISTRY.get(name)
    if exp is None:
        raise ValueError(f"unknown experiment {name!r}; registered: "
                         f"{list(_EXPERIMENT_REGISTRY)}")
    return exp


def all_experiments() -> List[Experiment]:
    """Every registered experiment, registration (= paper) order."""
    return list(_EXPERIMENT_REGISTRY.values())


def experiments_for(spec: MemorySpec) -> List[Experiment]:
    return [e for e in all_experiments() if e.available_on(spec)]


def plan_experiment(experiment: "Experiment | str", spec: MemorySpec = HBM,
                    *, quick: bool = False, bench: bool = False,
                    **options) -> Tuple[List[PlannedPoint], Dict[str, Any]]:
    """Resolve options and lay one experiment's keyed grid WITHOUT
    executing it.

    This is the request-level entry point: the campaign service
    (repro/service/campaign.py) lowers each accepted request through it,
    then batches the returned points onto its own (coalescing, fault-
    tolerant) Sweep and finishes with `Experiment.derive`.  Returns the
    ``(key, SweepPoint)`` pairs in plan order plus the resolved options
    `derive` must be called with.
    """
    exp = (get_experiment(experiment) if isinstance(experiment, str)
           else experiment)
    if not exp.available_on(spec):
        raise ValueError(
            f"experiment {exp.name!r} needs an inter-channel switch, which "
            f"the {spec.name} controller does not have (Sec. IV-D)")
    opts = exp.options(quick=quick, bench=bench, **options)
    return exp.plan(spec, opts), opts


def backend_capability_gap(backend, planned: List[PlannedPoint]
                           ) -> Optional[str]:
    """Why `backend` cannot execute a plan — None when it can.

    Serial-latency points need per-transaction timers
    (`supports_latency`, DESIGN.md §2); contention points need a
    multi-engine path (`supports_contention`, DESIGN.md §8).  The
    campaign service uses a non-None gap as a degradation trigger
    (pallas -> sim) instead of an error.
    """
    impl = get_backend(backend) if isinstance(backend, str) else backend
    if not impl.supports_latency and any(
            pt.kind == KIND_LATENCY for _, pt in planned):
        return (f"needs serial-latency measurements, which backend "
                f"{impl.name!r} does not provide (supports_latency=False)")
    if not impl.supports_contention and any(
            pt.kind == KIND_CONTENTION for _, pt in planned):
        return (f"needs multi-engine contention support, which backend "
                f"{impl.name!r} does not provide "
                f"(supports_contention=False)")
    return None


def run_experiment(experiment: "Experiment | str", spec: MemorySpec = HBM,
                   backend: str = "sim", *, quick: bool = False,
                   bench: bool = False, **options) -> Any:
    """Lower one experiment spec onto a Sweep and reduce the results.

    The whole grid executes as one batched `Sweep.run()` (memoized,
    channel-broadcast on deterministic backends); `derive` only ever sees
    ``(key, value)`` pairs in plan order.
    """
    exp = (get_experiment(experiment) if isinstance(experiment, str)
           else experiment)
    planned, opts = plan_experiment(exp, spec, quick=quick, bench=bench,
                                    **options)
    gap = backend_capability_gap(backend, planned)
    if gap is not None:
        raise ValueError(
            f"experiment {exp.name!r} {gap}; use the sim backend "
            f"(DESIGN.md §2/§8)")
    sweep = Sweep(spec, backend)
    for _, pt in planned:
        sweep.add_point(pt)
    values = [r.value for r in sweep.run()]
    keyed = [(key, v) for (key, _), v in zip(planned, values)]
    return exp.derive(spec, keyed, opts)


# ---------------------------------------------------------------------------
# grid/derive helpers
# ---------------------------------------------------------------------------


def _tp_point(p: RSTParams, policy=None, channel=0, dst_channel=None,
              op="read") -> SweepPoint:
    return SweepPoint(p, policy, channel, dst_channel, op, KIND_THROUGHPUT)


def _lat_point(p: RSTParams, channel=0, dst_channel=None,
               switch_enabled=None, op="read", num_engines=1,
               arbitration="round_robin", burst_beats=1) -> SweepPoint:
    return SweepPoint(p, None, channel, dst_channel, op, KIND_LATENCY,
                      switch_enabled, num_engines=num_engines,
                      arbitration=arbitration, burst_beats=burst_beats)


def _cont_point(p: RSTParams, num_engines, policy=None, channel=0,
                dst_channel=None, op="read", arbitration="round_robin",
                burst_beats=1, placement="same_channel",
                mix=None) -> SweepPoint:
    return SweepPoint(p, policy, channel, dst_channel, op, KIND_CONTENTION,
                      num_engines=num_engines, arbitration=arbitration,
                      burst_beats=burst_beats, placement=placement, mix=mix)


def _bursts(spec: MemorySpec, bursts) -> Tuple[int, ...]:
    return tuple(bursts) if bursts else (spec.min_burst, 2 * spec.min_burst)


def _categories(spec: MemorySpec, trace, extra_cycles: int = 0
                ) -> Dict[str, float]:
    module = LatencyModule()
    return module.category_latencies(module.capture(trace), spec,
                                     extra_cycles)


# ---------------------------------------------------------------------------
# Fig. 4 — refresh spikes
# ---------------------------------------------------------------------------


def _fig4_plan(spec, o):
    p = RSTParams(n=o["n"], b=spec.min_burst, s=64, w=0x1000000)
    return [(p, _lat_point(p))]


def _fig4_derive(spec, keyed, o):
    (p, trace), = keyed
    return {
        "latency_cycles": trace.cycles,
        "refresh_hits": trace.refresh_hits,
        "estimated_refresh_interval_ns":
            refresh_interval_estimate(trace, spec),
        "params": p,
    }


register_experiment(Experiment(
    name="fig4_refresh",
    artifact="Fig. 4",
    title="Serial-read latency timeline with periodic refresh spikes",
    plan=_fig4_plan,
    derive=_fig4_derive,
    defaults={"n": 1024},
    summarize=lambda spec, r:
        f"tREFI_est_ns={r['estimated_refresh_interval_ns']:.0f}",
    flatten=lambda spec, r: [
        ("tREFI_ns", f"{r['estimated_refresh_interval_ns']:.0f}"),
        ("spikes", str(int(r["refresh_hits"].sum()))),
    ],
))


# ---------------------------------------------------------------------------
# Fig. 5 / Table IV — idle page hit/closed/miss latency
# ---------------------------------------------------------------------------


def _table4_plan(spec, o):
    # The paper's two-stride probe: a small stride isolates hit+closed, a
    # page-crossing stride forces misses.  Switch disabled (footnote 6/9).
    small = RSTParams(n=o["n"], b=spec.min_burst, s=128, w=0x1000000)
    large = RSTParams(n=o["n"], b=spec.min_burst, s=128 * 1024, w=0x1000000)
    return [("small", _lat_point(small)), ("large", _lat_point(large))]


def _table4_derive(spec, keyed, o):
    traces = dict(keyed)
    cats_small = _categories(spec, traces["small"])
    cats_large = _categories(spec, traces["large"])
    return {
        name: {"cycles": cyc, "ns": cyc * spec.cycle_ns}
        for name, cyc in (("page_hit", cats_small["hit"]),
                          ("page_closed", cats_small["closed"]),
                          ("page_miss", cats_large["miss"]))
    }


register_experiment(Experiment(
    name="table4_idle_latency",
    artifact="Table IV / Fig. 5",
    title="Idle page hit/closed/miss latency",
    plan=_table4_plan,
    derive=_table4_derive,
    defaults={"n": 1024},
    summarize=lambda spec, r:
        ";".join(f"{k}={v['ns']:.1f}ns" for k, v in r.items()),
    flatten=lambda spec, r: [
        (k, f"{v['cycles']}cyc/{v['ns']:.1f}ns") for k, v in r.items()],
))


# ---------------------------------------------------------------------------
# Fig. 6 — address-mapping policy × stride × burst throughput
# ---------------------------------------------------------------------------


def _fig6_plan(spec, o):
    out = []
    for policy in policies_for(spec):
        for b in _bursts(spec, o["bursts"]):
            for s in o["strides"]:
                if s < b:
                    continue
                p = RSTParams(n=o["n"], b=b, s=s, w=o["w"])
                out.append(((policy, b, s), _tp_point(p, policy=policy)))
    return out


def _fig6_derive(spec, keyed, o):
    results = {policy: {b: {} for b in _bursts(spec, o["bursts"])}
               for policy in policies_for(spec)}
    for (policy, b, s), r in keyed:
        results[policy][b][s] = r.gbps
    return results


def _fig6_summarize(spec, r):
    per_s = r[DEFAULT_POLICY[spec.name]][spec.min_burst]
    best_seq = per_s[min(per_s)]
    return f"default_seq_gbps={best_seq:.2f};policies={len(r)}"


register_experiment(Experiment(
    name="fig6_address_mapping",
    artifact="Fig. 6",
    title="Throughput for every address-mapping policy x stride x burst",
    plan=_fig6_plan,
    derive=_fig6_derive,
    defaults={"strides": (64, 128, 256, 512, 1024, 2048, 4096, 8192,
                          16384, 32768),
              "bursts": None, "w": 0x10000000, "n": 4096},
    quick={"strides": (64, 1024, 8192), "n": 1024},
    summarize=_fig6_summarize,
    flatten=lambda spec, r: [
        (f"{pol}_B{b}_S{s}", f"{gbps:.2f}")
        for pol, per_b in r.items()
        for b, per_s in per_b.items()
        for s, gbps in per_s.items()],
))


# ---------------------------------------------------------------------------
# Fig. 7 — working-set locality (W=8K vs W=256M)
# ---------------------------------------------------------------------------

_FIG7_WINDOWS = (8 * 1024, 256 * MB)


def _fig7_plan(spec, o, op="read"):
    # Combinations with S < B or S > W violate the RST constraints
    # (Table I) and are omitted — consumers must guard lookups.
    out = []
    for w in _FIG7_WINDOWS:
        for b in _bursts(spec, o["bursts"]):
            for s in o["strides"]:
                if s < b or s > w:
                    continue
                p = RSTParams(n=o["n"], b=b, s=s, w=w)
                out.append(((w, b, s), _tp_point(p, op=op)))
    return out


def _fig7_derive(spec, keyed, o):
    results = {w: {b: {} for b in _bursts(spec, o["bursts"])}
               for w in _FIG7_WINDOWS}
    for (w, b, s), r in keyed:
        results[w][b][s] = r.gbps
    return results


def _fig7_summarize(spec, r):
    b, s = spec.min_burst, 4096
    try:
        local, base = r[8 * 1024][b][s], r[256 * MB][b][s]
    except KeyError as e:
        # The headline point must exist; a miss is a bug, not a skip.
        raise KeyError(
            f"locality result is missing burst={b} stride={s}: {e}; "
            f"available strides per window: "
            f"{ {w: sorted(per_b.get(b, {})) for w, per_b in r.items()} }"
        ) from e
    return f"w8k_s4k_gbps={local:.2f};w256m_s4k_gbps={base:.2f}"


register_experiment(Experiment(
    name="fig7_locality",
    artifact="Fig. 7",
    title="W=8K (locality) vs W=256M (baseline) throughput",
    plan=_fig7_plan,
    derive=_fig7_derive,
    defaults={"strides": (64, 256, 1024, 4096, 16384), "bursts": None,
              "n": 4096},
    quick={"n": 1024},
    summarize=_fig7_summarize,
    flatten=lambda spec, r: [
        (f"W{w}_B{b}_S{s}", f"{gbps:.2f}")
        for w, per_b in r.items()
        for b, per_s in per_b.items()
        for s, gbps in per_s.items()],
))


# ---------------------------------------------------------------------------
# Table V — aggregate throughput, all channels
# ---------------------------------------------------------------------------


def _table5_params(spec, o) -> RSTParams:
    return RSTParams(n=o["n"], b=spec.min_burst, s=spec.min_burst,
                     w=0x10000000)


def _table5_plan(spec, o, op="read"):
    # All M engines hit their local channels simultaneously; channels are
    # independent (footnote 11), so the sweep evaluates one and broadcasts.
    p = _table5_params(spec, o)
    return [(c, _tp_point(p, channel=c, op=op))
            for c in range(spec.num_channels)]


def _table5_derive(spec, keyed, o):
    per_channel = [r.gbps for _, r in keyed]
    return {
        "per_channel_gbps": float(np.mean(per_channel)),
        "num_channels": len(per_channel),
        "total_gbps": float(np.sum(per_channel)),
        "theoretical_gbps": spec.peak_total_gbps,
        # The grid's parameters, so register-faithful hosts (the
        # ShuhaiCampaign shim) can mirror them into their engines.
        "params": _table5_params(spec, o),
    }


register_experiment(Experiment(
    name="table5_total_throughput",
    artifact="Table V",
    title="Aggregate sequential-read throughput over all channels",
    plan=_table5_plan,
    derive=_table5_derive,
    defaults={"n": 8192},
    bench_label="table5_total",
    summarize=lambda spec, r: (f"total_gbps={r['total_gbps']:.1f};"
                               f"per_channel={r['per_channel_gbps']:.2f}"),
    flatten=lambda spec, r: [("total_gbps", f"{r['total_gbps']:.1f}")],
))


# ---------------------------------------------------------------------------
# Table VI — switch distance latency (switched specs only)
# ---------------------------------------------------------------------------


def _table6_plan(spec, o):
    small = RSTParams(n=o["n"], b=spec.min_burst, s=128, w=0x1000000)
    large = RSTParams(n=o["n"], b=spec.min_burst, s=128 * 1024, w=0x1000000)
    out = []
    for ch in range(spec.num_channels):
        for label, p in (("small", small), ("large", large)):
            out.append(((ch, label),
                        _lat_point(p, channel=ch,
                                   dst_channel=o["dst_channel"],
                                   switch_enabled=True)))
    return out


def _table6_derive(spec, keyed, o):
    sw = SwitchModel(topology_for(spec), enabled=True)
    traces = dict(keyed)
    out = {}
    for ch in range(spec.num_channels):
        extra = sw.distance_extra_cycles(ch, o["dst_channel"]) + \
            spec.switch_penalty
        cats = _categories(spec, traces[(ch, "small")], extra)
        cats_miss = _categories(spec, traces[(ch, "large")], extra)
        out[ch] = {"hit": cats["hit"], "closed": cats["closed"],
                   "miss": cats_miss["miss"]}
    return out


register_experiment(Experiment(
    name="table6_switch_latency",
    artifact="Table VI",
    title="Idle latency from every AXI channel to one channel, switch on",
    plan=_table6_plan,
    derive=_table6_derive,
    defaults={"dst_channel": 0, "n": 1024},
    requires_switch=True,
    summarize=lambda spec, r: (
        f"hit_ch0={r[0]['hit']}cyc;"
        f"hit_ch{max(r)}={r[max(r)]['hit']}cyc;"
        f"spread={r[max(r)]['hit'] - r[0]['hit']}cyc"),
    flatten=lambda spec, r: [
        (f"ch{ch}_hit", f"{r[ch]['hit']}cyc")
        for ch in range(0, spec.num_channels,
                        topology_for(spec).axi_per_switch)],
))


# ---------------------------------------------------------------------------
# Fig. 8 — switch throughput (switched specs only)
# ---------------------------------------------------------------------------


def _fig8_plan(spec, o):
    # One AXI channel per mini-switch; the non-blocking switch broadcasts.
    out = []
    step = topology_for(spec).axi_per_switch
    for sw in range(spec.num_channels // step):
        ch = sw * step
        for s in o["strides"]:
            p = RSTParams(n=o["n"], b=2 * spec.min_burst, s=s, w=0x1000000)
            out.append(((ch, s),
                        _tp_point(p, channel=ch,
                                  dst_channel=o["dst_channel"])))
    return out


def _fig8_derive(spec, keyed, o):
    out = {}
    for (ch, s), r in keyed:
        out.setdefault(ch, {})[s] = r.gbps
    return out


def _fig8_summarize(spec, r):
    s0 = min(next(iter(r.values())))
    vals = [r[ch][s0] for ch in r]
    return f"min_gbps={min(vals):.2f};max_gbps={max(vals):.2f}"


register_experiment(Experiment(
    name="fig8_switch_throughput",
    artifact="Fig. 8",
    title="Throughput from one AXI channel per mini-switch, switch on",
    plan=_fig8_plan,
    derive=_fig8_derive,
    defaults={"dst_channel": 0, "strides": (64, 256, 1024, 4096),
              "n": 200000},
    bench={"strides": (64, 1024)},
    requires_switch=True,
    summarize=_fig8_summarize,
    flatten=lambda spec, r: [
        (f"ch{ch}_S{s}", f"{per_s[s]:.2f}")
        for ch, per_s in r.items() for s in per_s],
))


# ---------------------------------------------------------------------------
# Write-path experiment family (paper Sec. IV; write-bandwidth results of
# Choi et al. 2020 and the duplex findings of Li et al. 2020).  These run
# on every registered memory system and are benchmarked on all four
# built-ins (bench_specs), not just the measured hbm/ddr4 pair.
# ---------------------------------------------------------------------------

_ALL_BUILTIN_SPECS = ("hbm", "ddr4", "hbm3", "ddr3")

# The write variants reuse the read experiments' plan/derive/summarize
# bodies with the traffic direction flipped — one grid definition per
# artifact, so a grid fix applies to both directions.
register_experiment(Experiment(
    name="table5_write_throughput",
    artifact="Table V (write)",
    title="Aggregate sequential-write throughput over all channels",
    plan=functools.partial(_table5_plan, op="write"),
    derive=_table5_derive,
    defaults={"n": 8192},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=lambda spec, r: (f"total_gbps={r['total_gbps']:.1f};"
                               f"per_channel={r['per_channel_gbps']:.2f}"),
    flatten=lambda spec, r: [("total_gbps", f"{r['total_gbps']:.1f}")],
))


register_experiment(Experiment(
    name="fig7_write_locality",
    artifact="Fig. 7 (write)",
    title="Write-path W=8K (locality) vs W=256M (baseline) throughput",
    plan=functools.partial(_fig7_plan, op="write"),
    derive=_fig7_derive,
    defaults={"strides": (64, 256, 1024, 4096, 16384), "bursts": None,
              "n": 4096},
    quick={"n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_fig7_summarize,
    flatten=lambda spec, r: [
        (f"W{w}_B{b}_S{s}", f"{gbps:.2f}")
        for w, per_b in r.items()
        for b, per_s in per_b.items()
        for s, gbps in per_s.items()],
))


_DUPLEX_OPS = ("read", "write", "duplex")


def _duplex_plan(spec, o):
    # Same RST tuple in all three directions so the derive can report the
    # duplex penalty as a ratio against pure reads at each stride.  The
    # true sequential point (S = min burst) is always present — it anchors
    # the summarize headline.
    strides = dict.fromkeys(
        (spec.min_burst,) + tuple(s for s in o["strides"]
                                  if s >= spec.min_burst))
    out = []
    for s in strides:
        p = RSTParams(n=o["n"], b=spec.min_burst, s=s, w=o["w"])
        for op in _DUPLEX_OPS:
            out.append(((op, s), _tp_point(p, op=op)))
    return out


def _duplex_derive(spec, keyed, o):
    results = {op: {} for op in _DUPLEX_OPS}
    for (op, s), r in keyed:
        results[op][s] = r.gbps
    return results


def _duplex_summarize(spec, r):
    s0 = spec.min_burst           # the sequential anchor the plan pins
    ratio = r["duplex"][s0] / r["read"][s0] if r["read"][s0] else 0.0
    return (f"seq_read_gbps={r['read'][s0]:.2f};"
            f"seq_write_gbps={r['write'][s0]:.2f};"
            f"seq_duplex_gbps={r['duplex'][s0]:.2f};"
            f"duplex_ratio={ratio:.2f}")


register_experiment(Experiment(
    name="duplex_rw_sweep",
    artifact="Sec. IV (duplex)",
    title="Read vs write vs mixed read/write throughput across strides",
    plan=_duplex_plan,
    derive=_duplex_derive,
    defaults={"strides": (64, 256, 1024, 4096, 16384), "w": 0x10000000,
              "n": 4096},
    quick={"strides": (64, 1024, 4096), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_duplex_summarize,
    flatten=lambda spec, r: [
        (f"{op}_S{s}", f"{gbps:.2f}")
        for op, per_s in r.items() for s, gbps in per_s.items()],
))


# ---------------------------------------------------------------------------
# Per-transaction instrumentation + multi-engine contention family
# (DESIGN.md §8; the serial write-latency classes the op-aware latency
# module captures, and the shared-port contention scenarios of Choi et
# al. 2020 / Zohouri & Matsuoka 2019).  All three run on every registered
# memory system and are benchmarked on all four built-ins.
# ---------------------------------------------------------------------------


def _table4w_plan(spec, o):
    # The Table-IV two-stride probe, driven through the *write* module: a
    # small stride isolates hit+closed (no precharge, read anchors), a
    # page-crossing stride forces tWR-bearing misses.
    small = RSTParams(n=o["n"], b=spec.min_burst, s=128, w=0x1000000)
    large = RSTParams(n=o["n"], b=spec.min_burst, s=128 * 1024, w=0x1000000)
    return [("small", _lat_point(small, op="write")),
            ("large", _lat_point(large, op="write"))]


def _table4w_derive(spec, keyed, o):
    traces = dict(keyed)
    module = LatencyModule(op="write", counter_bits=o["counter_bits"])
    cats_small = module.category_latencies(module.capture(traces["small"]),
                                           spec)
    cats_large = module.category_latencies(module.capture(traces["large"]),
                                           spec)
    out = {
        name: {"cycles": cyc, "ns": cyc * spec.cycle_ns}
        for name, cyc in (("page_hit", cats_small["hit"]),
                          ("page_closed", cats_small["closed"]),
                          ("page_miss", cats_large["miss"]))
    }
    # The write-direction delta the capture path used to silently drop:
    # miss latency above the read anchor = the write-recovery segment.
    out["write_recovery"] = {
        "cycles": out["page_miss"]["cycles"] - spec.lat_page_miss,
        "ns": (out["page_miss"]["cycles"] - spec.lat_page_miss)
              * spec.cycle_ns,
    }
    return out


register_experiment(Experiment(
    name="table4_write_latency_classes",
    artifact="Table IV (write)",
    title="Serial write latency classes (tWR-bearing page-miss path)",
    plan=_table4w_plan,
    derive=_table4w_derive,
    defaults={"n": 1024, "counter_bits": 8},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=lambda spec, r: (
        ";".join(f"{k}={v['ns']:.1f}ns" for k, v in r.items()
                 if k != "write_recovery")
        + f";tWR={r['write_recovery']['cycles']}cyc"),
    flatten=lambda spec, r: [
        (k, f"{v['cycles']}cyc/{v['ns']:.1f}ns") for k, v in r.items()],
))


def _fig9_plan(spec, o):
    # One sequential-stream engine ladder on one shared channel port —
    # the Fig. 9-style scaling curve of a multi-PE design (Choi et al.).
    # `arbitration`/`burst_beats` select the grant granularity (§9);
    # `benchmarks.run --arbitration POLICY --burst B` overrides them.
    p = RSTParams(n=o["n"], b=spec.min_burst, s=spec.min_burst, w=o["w"])
    return [(n_eng, _cont_point(p, n_eng, op=o["op"],
                                arbitration=o["arbitration"],
                                burst_beats=o["burst_beats"]))
            for n_eng in o["engines"]]


def _fig9_derive(spec, keyed, o):
    return {
        n_eng: {
            "aggregate_gbps": r.aggregate_gbps,
            "per_engine_gbps": r.per_engine_gbps,
            "queueing_delay_cycles": r.queueing_delay_cycles,
            "bound": r.bound,
        }
        for n_eng, r in keyed
    }


def _fig9_summarize(spec, r):
    n1, nmax = min(r), max(r)
    agg1, aggn = r[n1]["aggregate_gbps"], r[nmax]["aggregate_gbps"]
    scaling = aggn / (nmax / n1 * agg1) if agg1 else 0.0
    return (f"agg_x{n1}={agg1:.2f};agg_x{nmax}={aggn:.2f};"
            f"per_engine_x{nmax}={r[nmax]['per_engine_gbps']:.2f};"
            f"qdelay_x{nmax}={r[nmax]['queueing_delay_cycles']:.1f}cyc;"
            f"scaling={scaling:.2f}")


register_experiment(Experiment(
    name="fig9_channel_contention",
    artifact="Fig. 9 (contention)",
    title="N engines sharing one channel port: aggregate + per-engine",
    plan=_fig9_plan,
    derive=_fig9_derive,
    defaults={"engines": (1, 2, 4, 8), "n": 4096, "w": 0x1000000,
              "op": "read", "arbitration": "round_robin", "burst_beats": 1},
    quick={"engines": (1, 4), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_fig9_summarize,
    flatten=lambda spec, r: [
        (f"N{n_eng}_{key}", f"{val:.2f}" if isinstance(val, float) else val)
        for n_eng, per in r.items() for key, val in per.items()],
))


def _cont_sweep_plan(spec, o):
    out = []
    for n_eng in o["engines"]:
        for s in o["strides"]:
            if s < spec.min_burst:
                continue
            p = RSTParams(n=o["n"], b=spec.min_burst, s=s, w=o["w"])
            out.append(((n_eng, s),
                        _cont_point(p, n_eng, op=o["op"],
                                    arbitration=o["arbitration"],
                                    burst_beats=o["burst_beats"])))
    return out


def _cont_sweep_derive(spec, keyed, o):
    gbps: Dict[int, Dict[int, float]] = {}
    queueing: Dict[int, Dict[int, float]] = {}
    for (n_eng, s), r in keyed:
        gbps.setdefault(n_eng, {})[s] = r.aggregate_gbps
        queueing.setdefault(n_eng, {})[s] = r.queueing_delay_cycles
    base = gbps[min(gbps)]
    n1 = min(gbps)
    efficiency = {
        n_eng: {s: (per_s[s] / ((n_eng / n1) * base[s]) if base[s] else 0.0)
                for s in per_s}
        for n_eng, per_s in gbps.items()
    }
    return {"gbps": gbps, "efficiency": efficiency, "queueing": queueing}


def _cont_sweep_summarize(spec, r):
    nmax = max(r["gbps"])
    s0 = min(r["gbps"][nmax])
    return (f"agg_x{nmax}_S{s0}={r['gbps'][nmax][s0]:.2f};"
            f"eff_x{nmax}_S{s0}={r['efficiency'][nmax][s0]:.2f};"
            f"qdelay_x{nmax}_S{s0}={r['queueing'][nmax][s0]:.1f}cyc")


register_experiment(Experiment(
    name="contention_scaling_sweep",
    artifact="contention (scaling)",
    title="Engine-count x stride contention grid with scaling efficiency",
    plan=_cont_sweep_plan,
    derive=_cont_sweep_derive,
    defaults={"engines": (1, 2, 4, 8), "strides": (64, 1024, 4096),
              "w": 0x1000000, "n": 4096, "op": "read",
              "arbitration": "round_robin", "burst_beats": 1},
    quick={"engines": (1, 4), "strides": (64, 1024), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_cont_sweep_summarize,
    flatten=lambda spec, r: [
        (f"N{n_eng}_S{s}", f"{gbps:.2f}")
        for n_eng, per_s in r["gbps"].items() for s, gbps in per_s.items()],
))


# ---------------------------------------------------------------------------
# Arbitration-aware contention family (DESIGN.md §9): grant-granularity
# ladders, the cross-channel placement split of Fig. 9, and the contended
# latency classes the doubled-anchor classifier separates.  All three run
# on every registered memory system and are benchmarked on all four
# built-ins.
# ---------------------------------------------------------------------------


def _arb_ladder(o) -> List[Tuple[str, int]]:
    """(policy, burst_beats) rungs: round robin, the burst ladder, and the
    exclusive serialized bound — ordered by grant size."""
    return ([("round_robin", 1)]
            + [("burst", bb) for bb in o["burst_ladder"]]
            + [("exclusive", 1)])


def _arb_sweep_plan(spec, o):
    p = RSTParams(n=o["n"], b=spec.min_burst, s=spec.min_burst, w=o["w"])
    out = []
    for n_eng in o["engines"]:
        for policy, bb in _arb_ladder(o):
            out.append(((n_eng, policy, bb),
                        _cont_point(p, n_eng, op=o["op"], arbitration=policy,
                                    burst_beats=bb)))
    return out


def _arb_sweep_derive(spec, keyed, o):
    out: Dict[int, Dict] = {}
    for (n_eng, policy, bb), r in keyed:
        per = out.setdefault(n_eng, {"burst": {}})
        entry = {
            "aggregate_gbps": r.aggregate_gbps,
            "queueing_delay_cycles": r.queueing_delay_cycles,
            # Measuring backends put no such key in detail (the Backend
            # protocol doesn't require it); NaN marks "not modeled".
            "grant_head_wait_cycles":
                r.detail.get("grant_head_wait_cycles", float("nan")),
            "bound": r.bound,
        }
        if policy == "burst":
            per["burst"][bb] = entry
        else:
            per[policy] = entry
    return out


def _arb_sweep_summarize(spec, r):
    nmax = max(r)
    per = r[nmax]
    bb_max = max(per["burst"])
    rr, ex = per["round_robin"], per["exclusive"]
    burst = per["burst"][bb_max]
    # How much of the round-robin collapse does the largest burst grant
    # claw back, relative to the serialized (exclusive) bound?
    span = ex["aggregate_gbps"] - rr["aggregate_gbps"]
    recovered = ((burst["aggregate_gbps"] - rr["aggregate_gbps"]) / span
                 if span else 1.0)
    return (f"rr_x{nmax}={rr['aggregate_gbps']:.2f};"
            f"burst{bb_max}_x{nmax}={burst['aggregate_gbps']:.2f};"
            f"exclusive_x{nmax}={ex['aggregate_gbps']:.2f};"
            f"recovered={recovered:.2f}")


register_experiment(Experiment(
    name="arbitration_granularity_sweep",
    artifact="contention (arbitration)",
    title="Grant-granularity ladder: round robin -> burst grants -> exclusive",
    plan=_arb_sweep_plan,
    derive=_arb_sweep_derive,
    defaults={"engines": (2, 4), "burst_ladder": (4, 16, 64),
              "n": 4096, "w": 0x1000000, "op": "read"},
    quick={"engines": (4,), "burst_ladder": (16,), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_arb_sweep_summarize,
    flatten=lambda spec, r: [
        (f"N{n_eng}_{policy if policy != 'burst' else f'burst{bb}'}",
         f"{entry['aggregate_gbps']:.2f}")
        for n_eng, per in r.items()
        for policy, bb, entry in (
            [("round_robin", 1, per["round_robin"])]
            + [("burst", bb, e) for bb, e in per["burst"].items()]
            + [("exclusive", 1, per["exclusive"])])],
))


def _fig9x_plan(spec, o):
    # The Fig. 9 engine ladder split by fabric placement: one shared port
    # (the PR 4 worst case), different channels of one mini-switch (the
    # switch-aggregate term), and channels across the lateral bridge (the
    # cross-switch collapse).  Flat fabrics degrade cross_switch to
    # same_switch inside the engine (detail["placement_degraded"]).
    p = RSTParams(n=o["n"], b=spec.min_burst, s=spec.min_burst, w=o["w"])
    out = []
    for placement in o["placements"]:
        for n_eng in o["engines"]:
            out.append(((placement, n_eng),
                        _cont_point(p, n_eng, op=o["op"],
                                    arbitration=o["arbitration"],
                                    burst_beats=o["burst_beats"],
                                    placement=placement)))
    return out


def _fig9x_derive(spec, keyed, o):
    out: Dict[str, Dict[int, Dict]] = {}
    for (placement, n_eng), r in keyed:
        out.setdefault(placement, {})[n_eng] = {
            "aggregate_gbps": r.aggregate_gbps,
            "per_engine_gbps": r.per_engine_gbps,
            "bound": r.bound,
            "degraded": bool(r.detail.get("placement_degraded", 0.0)),
        }
    return out


def _fig9x_summarize(spec, r):
    nmax = max(next(iter(r.values())))
    parts = [f"{plc}_x{nmax}={per[nmax]['aggregate_gbps']:.2f}"
             for plc, per in r.items()]
    same = r.get("same_switch", {}).get(nmax)
    cross = r.get("cross_switch", {}).get(nmax)
    if same and cross and same["aggregate_gbps"]:
        parts.append(
            f"cross_ratio={cross['aggregate_gbps'] / same['aggregate_gbps']:.2f}")
    return ";".join(parts)


register_experiment(Experiment(
    name="fig9_cross_switch_contention",
    artifact="Fig. 9 (placement)",
    title="Engine ladder split by placement: same channel/switch/cross-switch",
    plan=_fig9x_plan,
    derive=_fig9x_derive,
    defaults={"engines": (1, 2, 4), "placements": PLACEMENTS,
              "n": 4096, "w": 0x1000000, "op": "read",
              "arbitration": "round_robin", "burst_beats": 1},
    quick={"engines": (1, 4), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_fig9x_summarize,
    flatten=lambda spec, r: [
        (f"{plc}_N{n_eng}", f"{per['aggregate_gbps']:.2f}")
        for plc, per_n in r.items() for n_eng, per in per_n.items()],
))


def _cont_lat_plan(spec, o):
    # A hit-regime stream captured under contention: grant heads carry the
    # arbitration rotation's wait, grant riders post at the uncontended
    # anchors — the bimodal distribution classify_contended separates.
    # N=1 is always planned: it is the baseline the queueing shift is
    # derived from (the shift the contended capture sees is (N-1)*B*mean
    # of the uncontended trace, DESIGN.md §9).
    p = RSTParams(n=o["n"], b=spec.min_burst, s=128, w=0x1000000)
    engines = dict.fromkeys((1,) + tuple(o["engines"]))
    return [(n_eng, _lat_point(p, op=o["op"], num_engines=n_eng,
                               arbitration=o["arbitration"],
                               burst_beats=o["burst_beats"]))
            for n_eng in engines]


def _cont_lat_derive(spec, keyed, o):
    traces = dict(keyed)
    base = traces[1]
    module = LatencyModule(op=o["op"], counter_bits=o["counter_bits"])
    out = {}
    for n_eng, trace in traces.items():
        # The shift the trace actually carries is the timing model's own
        # delay vector (grant heads pay the rotation; sample 0 is always
        # a head), so the classifier anchors can never drift from the
        # model's queueing formula.
        delay = _contended_latency_delay(base.cycles, n_eng,
                                         o["arbitration"], o["burst_beats"])
        head_wait = float(delay[0]) if len(delay) else 0.0
        counts = module.classify_contended(module.capture(trace), spec,
                                           head_wait)
        out[n_eng] = {"counts": counts,
                      "grant_head_wait_cycles": head_wait,
                      "mean_cycles": float(np.mean(trace.cycles))}
    return out


def _cont_lat_summarize(spec, r):
    nmax = max(r)
    c = r[nmax]["counts"]
    queued = sum(v for k, v in c.items() if k.endswith("_queued"))
    unqueued = sum(v for k, v in c.items()
                   if not k.endswith("_queued") and k != "refresh")
    return (f"x{nmax}_queued={queued};x{nmax}_unqueued={unqueued};"
            f"head_wait_x{nmax}={r[nmax]['grant_head_wait_cycles']:.1f}cyc;"
            f"mean_x{nmax}={r[nmax]['mean_cycles']:.1f}cyc")


register_experiment(Experiment(
    name="contended_latency_classes",
    artifact="Table IV (contended)",
    title="Contended serial-latency classes under burst-grant arbitration",
    plan=_cont_lat_plan,
    derive=_cont_lat_derive,
    defaults={"engines": (4,), "arbitration": "burst", "burst_beats": 8,
              "n": 1024, "op": "read", "counter_bits": 16},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_cont_lat_summarize,
    flatten=lambda spec, r: [
        (f"N{n_eng}_{cls}", str(cnt))
        for n_eng, per in r.items() for cls, cnt in per["counts"].items()],
))


# ---------------------------------------------------------------------------
# Heterogeneous engine-mix family (DESIGN.md §13): named read/write/duplex
# blends of the Fig. 9 contention ladder — per-engine (params, op) tuples
# instead of N identical engines.  Runs on every registered memory system
# and is benchmarked on all four built-ins.
# ---------------------------------------------------------------------------

_MIX_PRESETS = (("read_heavy", "3r+1w"),
                ("write_heavy", "1r+3w"),
                ("balanced", "2r+2w"),
                ("duplex_spiked", "2r+1w+1d"))


def _mix_sweep_plan(spec, o):
    # Every engine in a named blend shares one RST tuple (sequential
    # stream, min burst) so the blends differ only in their traffic-
    # direction composition — the axis this family isolates.  The
    # arbitration rungs replay the §9 grant ladder under each blend.
    p = RSTParams(n=o["n"], b=spec.min_burst, s=spec.min_burst, w=o["w"])
    mixes = list(o["mixes"])
    if o["custom_mix"]:
        mixes.append(("custom", o["custom_mix"]))
    out = []
    for label, spec_str in mixes:
        mix = EngineMix.from_spec(spec_str, p)
        for policy, bb in o["arbitrations"]:
            out.append(((label, policy, bb),
                        _cont_point(p, len(mix), arbitration=policy,
                                    burst_beats=bb, mix=mix)))
    return out


def _mix_sweep_derive(spec, keyed, o):
    out: Dict[str, Dict] = {}
    for (label, policy, bb), r in keyed:
        out.setdefault(label, {})[(policy, bb)] = {
            "aggregate_gbps": r.aggregate_gbps,
            "per_engine_gbps": r.per_engine_gbps,
            "queueing_delay_cycles": r.queueing_delay_cycles,
            "op_switch_cycles": r.detail.get("op_switch_cycles",
                                             float("nan")),
            "bound": r.bound,
            "mix": r.mix.describe() if r.mix is not None else None,
        }
    return out


def _mix_sweep_summarize(spec, r):
    rung = next(iter(next(iter(r.values()))))   # first arbitration rung
    parts = [f"{label}={per[rung]['aggregate_gbps']:.2f}"
             for label, per in r.items()]
    opsw = max(per[rung]["op_switch_cycles"] for per in r.values())
    parts.append(f"max_opsw={opsw:.0f}cyc")
    return ";".join(parts)


register_experiment(Experiment(
    name="engine_mix_sweep",
    artifact="contention (mixes)",
    title="Heterogeneous engine blends: read/write/duplex mixes x grants",
    plan=_mix_sweep_plan,
    derive=_mix_sweep_derive,
    defaults={"mixes": _MIX_PRESETS, "custom_mix": None,
              "arbitrations": (("round_robin", 1), ("burst", 8),
                               ("exclusive", 1)),
              "n": 4096, "w": 0x1000000},
    quick={"mixes": _MIX_PRESETS[:2],
           "arbitrations": (("round_robin", 1),), "n": 1024},
    bench_specs=_ALL_BUILTIN_SPECS,
    summarize=_mix_sweep_summarize,
    flatten=lambda spec, r: [
        (f"{label}_{policy if policy != 'burst' else f'burst{bb}'}",
         f"{per[(policy, bb)]['aggregate_gbps']:.2f}")
        for label, per in r.items() for (policy, bb) in per],
))


# ---------------------------------------------------------------------------
# Grid cross-product — the full knob space of Sec. V/VI as one experiment.
# Runs on every backend; on `jaxgrid` the Sweep prefill lowers the whole
# product into one compiled jit+vmap call (core/timing_jax.py), which is
# what makes the 10^4+-point defaults interactive.
# ---------------------------------------------------------------------------


def _grid_xp_plan(spec, o):
    pols = (None,) + tuple(policies_for(spec))
    out = []
    for pol in pols:
        for s in o["strides"]:
            p = RSTParams(n=o["n"], b=spec.min_burst, s=s, w=o["w"])
            for op in o["ops"]:
                for n_eng in o["engines"]:
                    for arb, bb in o["arbitrations"]:
                        for plc in o["placements"]:
                            key = (pol or DEFAULT_POLICY[spec.name], s,
                                   op, n_eng, arb, bb, plc)
                            out.append((key, _cont_point(
                                p, n_eng, policy=pol, op=op,
                                arbitration=arb, burst_beats=bb,
                                placement=plc)))
    return out


def _grid_xp_derive(spec, keyed, o):
    gbps = {k: r.aggregate_gbps for k, r in keyed}
    best = max(gbps, key=gbps.__getitem__)
    worst = min(gbps, key=gbps.__getitem__)
    return {"points": len(gbps), "gbps": gbps,
            "best": {"key": best, "gbps": gbps[best]},
            "worst": {"key": worst, "gbps": gbps[worst]}}


def _grid_xp_summarize(spec, r):
    spread = (r["best"]["gbps"] / r["worst"]["gbps"]
              if r["worst"]["gbps"] else float("inf"))
    return (f"points={r['points']};best={r['best']['gbps']:.1f};"
            f"worst={r['worst']['gbps']:.2f};spread={spread:.0f}x")


register_experiment(Experiment(
    name="grid_cross_product",
    artifact="Sec. V-VI (grid)",
    title="Policy × stride × op × engines × arbitration × placement grid",
    plan=_grid_xp_plan,
    derive=_grid_xp_derive,
    defaults={"n": 4096, "w": 0x1000000, "strides": (64, 256, 1024),
              "ops": ("read", "write"), "engines": (1, 2, 4),
              "arbitrations": (("round_robin", 1), ("burst", 4)),
              "placements": PLACEMENTS},
    quick={"strides": (64,), "engines": (1, 4), "n": 1024},
    summarize=_grid_xp_summarize,
    flatten=lambda spec, r: [
        ("_".join(str(f) for f in k), f"{v:.2f}")
        for k, v in r["gbps"].items()],
))


# ---------------------------------------------------------------------------
# Experiment catalog (README.md section; `python -m benchmarks.run --catalog`)
# ---------------------------------------------------------------------------

CATALOG_BEGIN = "<!-- experiment-catalog:begin -->"
CATALOG_END = "<!-- experiment-catalog:end -->"


def _catalog_backends(planned: List[PlannedPoint]) -> str:
    """Backends that can execute a plan: serial-latency points need
    per-transaction timers (sim only, DESIGN.md §2); contention points
    need a multi-engine path (supports_contention, DESIGN.md §8)."""
    from repro.core.engine import available_backends
    needs_latency = any(pt.kind == KIND_LATENCY for _, pt in planned)
    needs_contention = any(pt.kind == KIND_CONTENTION for _, pt in planned)
    names = [name for name in available_backends()
             if (not needs_latency or get_backend(name).supports_latency)
             and (not needs_contention
                  or get_backend(name).supports_contention)]
    return ", ".join(names)


def catalog_rows() -> List[Tuple[str, ...]]:
    """One row per registered experiment, derived live from the registry."""
    from repro.core.hwspec import available_specs, spec_by_name
    specs = [spec_by_name(n) for n in available_specs()]
    rows = []
    for exp in all_experiments():
        spec = next(s for s in specs if exp.available_on(s))
        planned = exp.plan(spec, exp.options())
        systems = ("switched specs" if exp.requires_switch
                   else "all registered specs")
        rows.append((exp.name, exp.artifact,
                     f"{len(planned)} ({spec.name})",
                     _catalog_backends(planned), systems))
    return rows


def catalog_markdown() -> str:
    """The README's "Experiment catalog" table, generated from the registry
    (``python -m benchmarks.run --catalog``) so it can never drift."""
    lines = [
        CATALOG_BEGIN,
        "<!-- generated by `python -m benchmarks.run --catalog README.md`; "
        "do not edit by hand -->",
        "| experiment | paper artifact | grid points | backends | systems |",
        "|---|---|---|---|---|",
    ]
    for name, artifact, grid, backends, systems in catalog_rows():
        lines.append(
            f"| `{name}` | {artifact} | {grid} | {backends} | {systems} |")
    lines.append(CATALOG_END)
    return "\n".join(lines)
