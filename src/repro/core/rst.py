"""RST address-stream generation (paper Eq. 1), host- and device-side.

The address computation is deliberately trivial — `A + (i*S) % W` — because
the paper's engine computes it "with simple arithmetic, which in turn leads
to fewer FPGA resources and potentially higher frequency".  On TPU the same
property matters for a different reason: the index map must be cheap scalar
arithmetic so the Pallas grid pipeline can prefetch the next block while the
current one is in flight.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.params import RSTParams


def addresses_np(p: RSTParams, count: int | None = None) -> np.ndarray:
    """First `count` (default: one period, capped at N) transaction addresses."""
    if count is None:
        count = min(p.n, p.period)
    i = np.arange(count, dtype=np.int64)
    return p.a + (i * p.s) % p.w


def addresses_jnp(p: RSTParams, count: int) -> jnp.ndarray:
    i = jnp.arange(count, dtype=jnp.int64)
    return p.a + (i * p.s) % p.w


def block_params(p: RSTParams, block_bytes: int) -> Tuple[int, int, int]:
    """Translate byte-level RST params into Pallas block-index terms.

    Returns (stride_blocks, wset_blocks, base_block) such that the block
    index of transaction i is `base_block + (i * stride_blocks) % wset_blocks`
    when S >= block_bytes, matching Eq. 1 at block granularity.  These three
    integers are exactly what we feed the kernel through scalar prefetch.
    """
    if block_bytes <= 0 or block_bytes & (block_bytes - 1):
        raise ValueError(f"block_bytes must be a power of 2, got {block_bytes}")
    stride_blocks = max(1, p.s // block_bytes)
    wset_blocks = max(1, p.w // block_bytes)
    base_block = p.a // block_bytes
    return stride_blocks, wset_blocks, base_block


def checksum_ref(data: np.ndarray, p: RSTParams, elem_bytes: int) -> np.ndarray:
    """Oracle for the read-engine checksum: sum of every element each burst
    touches, over all N transactions (with wraparound repeats).

    `data` is the flat working buffer; the engine reads B bytes at each
    address T[i] and accumulates.  Used to validate the Pallas kernels.
    """
    flat = np.asarray(data).reshape(-1)
    epb = p.b // elem_bytes                      # elements per burst
    total = np.zeros((), dtype=np.float64)
    addrs = p.a + (np.arange(p.n, dtype=np.int64) * p.s) % p.w
    starts = addrs // elem_bytes
    for st in starts:
        total += flat[st:st + epb].astype(np.float64).sum()
    return total
