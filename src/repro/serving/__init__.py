from repro.serving.engine import (ContinuousBatchingEngine, Request,
                                  ServingStats)

__all__ = ["ContinuousBatchingEngine", "Request", "ServingStats"]
