"""Serving runtime: continuous batching over the model decode step.

Iteration-level batching (Orca-style) with fixed shapes, which is what TPU
serving wants: a constant number of slots equal to the compiled batch size;
every engine step advances EVERY active slot by exactly one token — a
forced prompt token for slots still in their prefill phase, or the
previously sampled token for slots in generation.  Finished slots (EOS or
max tokens) are reset and immediately reusable; shapes never change, so
one compiled decode_step serves the whole workload (the same philosophy as
the paper's single-bitstream runtime parameterization).

KV-cache layout is chosen with the Shuhai-derived autotuner
(core.autotune.choose_layout): decode sweeps `seq` while fetching
(kv_heads, head_dim) contiguously — the modeled-best layout keeps the
fetched dims minor, exactly how the paper picks an address-mapping policy
from measured curves.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import choose_layout
from repro.core.oracle import MemoryOracle


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request
    cursor: int = 0      # next prompt token index to feed

    @property
    def prefilling(self) -> bool:
        return self.cursor < len(self.req.prompt)


@dataclasses.dataclass
class ServingStats:
    admitted: int = 0
    completed: int = 0
    engine_steps: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0


class ContinuousBatchingEngine:
    def __init__(self, model, params, *, slots: int, max_seq: int,
                 eos_id: int = 0, dtype=jnp.float32):
        if model.cfg.is_encdec:
            raise ValueError("continuous batching engine serves decoder-only "
                             "models; use EncDecLM.decode_step directly")
        self.model = model
        self.params = params
        self.num_slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        cache = model.init_cache(batch_size=slots, max_seq=max_seq,
                                 dtype=dtype)
        self.cache = model.enable_slots(cache, slots)
        self.slots: List[Optional[_Slot]] = [None] * slots
        self.queue: Deque[Request] = deque()
        self.stats = ServingStats()
        self._decode = jax.jit(model.decode_step)
        cfg = model.cfg
        self.kv_layout = choose_layout(
            MemoryOracle(),
            {"seq": max_seq, "kv_heads": max(1, cfg.num_kv_heads),
             "head_dim": max(1, cfg.head_dim)},
            itemsize=2, iterate_dim="seq",
            fetch_dims=("kv_heads", "head_dim"))

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_seq:
            raise ValueError("request exceeds max_seq")
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.num_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.cache = self.model.reset_slot(self.cache, i)
                self.slots[i] = _Slot(req=req)
                self.stats.admitted += 1

    # ------------------------------------------------------------- engine
    def step(self) -> None:
        """One engine step: admit, advance every active slot by one token."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        feed = np.full((self.num_slots, 1), self.eos_id, np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.prefilling:
                feed[i, 0] = slot.req.prompt[slot.cursor]
            else:
                feed[i, 0] = slot.req.generated[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(feed))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats.engine_steps += 1

        for i, slot in enumerate(self.slots):
            if slot is None:
                # Idle slot decoded garbage at position 0; rewind its cursor
                # so its state stays inert until admission resets it.
                self.cache["slot_pos"] = self.cache["slot_pos"].at[i].set(0)
                continue
            if slot.prefilling:
                self.stats.prefill_tokens += 1
                last_prompt = slot.cursor == len(slot.req.prompt) - 1
                slot.cursor += 1
                if last_prompt:
                    self._emit(i, slot, int(nxt[i]))
            else:
                self._emit(i, slot, int(nxt[i]))

    def _emit(self, i: int, slot: _Slot, tok: int) -> None:
        slot.req.generated.append(tok)
        self.stats.decode_tokens += 1
        if tok == self.eos_id or len(slot.req.generated) >= \
                slot.req.max_new_tokens:
            slot.req.done = True
            self.stats.completed += 1
            self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> ServingStats:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.stats
