"""repro-lint: AST-driven invariant analysis for this repository.

The last three PRs each fixed a *silent* determinism bug (write captures
returning read anchors, an unreachable refresh threshold, parameters
reaching the timing model without reaching the Sweep memo key).  This
package enforces those invariants statically, before the code runs:

* ``cache_keys``     — REPRO-C*: memo/dedup key completeness in
  core/sweep.py and service/campaign.py (every parameter that flows into
  an evaluation participates in its cache key).
* ``oracle_parity``  — REPRO-O*: every public timing-model function has a
  loop oracle in ``_timing_reference.py`` and a parity test referencing
  both.
* ``capabilities``   — REPRO-B*: Backend subclasses declare the
  ``supports_*`` flag for every gated method they implement, or raise
  ``UnsupportedCapability``.
* ``kernel_shapes``  — REPRO-K*: pallas kernel scalar-prefetch operands,
  index maps and working buffers are consistent and int32-safe at the
  registered table bounds.

Run ``python -m repro.analysis.lint --baseline analysis_baseline.json``
(CI does, before the test matrix); see DESIGN.md §11 for the invariant
catalog.
"""
from repro.analysis.findings import Finding

__all__ = ["Finding"]
