"""REPRO-C*: memo/dedup cache-key completeness.

The bug family this prevents shipped twice before PR 5 hardened the Sweep
keys: a parameter (``placement``, ``arbitration``, ``burst_beats``) flowed
into an evaluation but not into the memo key, so two different grid
points served one cached result.  The checker re-derives, per cache-store
site, which ``SweepPoint`` fields the *stored value* transitively depends
on (``astutil.DepTracer``) and requires each to be covered by the key
expression.

Invariants:

* **REPRO-C001** — a cache/flight store's value depends on a traced field
  the key does not cover.
* **REPRO-C002** — a class used as (part of) a cache key is not a frozen
  ``eq`` dataclass.
* **REPRO-C003** — a keyword parameter of a public timing-model function
  has no corresponding ``SweepPoint`` field (direct or derived), i.e. the
  axis exists in the model but cannot be keyed by the sweep layer.
* **REPRO-C004** — the service dedup key omits request state: an
  ``ExperimentRequest`` field is excluded from comparison
  (``compare=False``) while the execution path reads it, or the response
  cache is keyed by less than the whole request.

Memo-cache stores (attribute name contains ``cache``) are checked
receiver-exclusively — the channel-broadcast invariant says engine
identity must not affect deterministic results.  Flight stores
(``flight`` in the name) coalesce on *non-deterministic* backends, where
the engine's own dependencies (its channel) must be part of the key, so
they are checked receiver-inclusively.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.astutil import (DepTracer, covers, dataclass_info,
                                    find_class, parse_module,
                                    statements_in_order)
from repro.analysis.findings import Finding

# Timing-model keyword parameters that no SweepPoint field matches by
# name, with the fields they are derived from (Engine.latency_config
# folds dst_channel + switch_enabled into switch_extra_cycles).
DERIVED_PARAMS: Dict[str, Tuple[str, ...]] = {
    "switch_extra_cycles": ("dst_channel", "switch_enabled"),
}

# Positional evaluation operands: params carries the RST tuple, mapping
# carries the policy, spec is fixed per Sweep/Engine instance.
_EXEMPT_PARAMS = frozenset({"p", "mapping", "spec", "trace"})

_TIMING_PUBLIC_KEYED = ("serial_latencies", "throughput",
                       "contended_throughput", "contended_throughput_mix")


def _rel(path: Path, root: Optional[Path]) -> str:
    if root is not None:
        try:
            return str(path.relative_to(root))
        except ValueError:
            pass
    return str(path)


def _class_store_findings(cls: ast.ClassDef, path: str,
                          point_class: str) -> List[Finding]:
    findings: List[Finding] = []
    for meth in cls.body:
        if not isinstance(meth, ast.FunctionDef):
            continue
        roots = [a.arg for a in meth.args.args if a.arg != "self"]
        roots += [a.arg for a in meth.args.kwonlyargs]
        if not roots:
            continue
        exclusive = DepTracer(roots, include_receivers=False)
        inclusive = DepTracer(roots, include_receivers=True)
        for stmt in statements_in_order(meth.body):
            store = _cache_store(stmt)
            if store is not None:
                attr, key_expr, value_expr = store
                tracer = inclusive if "flight" in attr else exclusive
                required = tracer.deps(value_expr)
                covered = tracer.deps(key_expr)
                missing = covers(required, covered)
                if missing:
                    fields = ", ".join(sorted(missing))
                    findings.append(Finding(
                        invariant="REPRO-C001",
                        path=path, line=stmt.lineno,
                        message=(f"{cls.name}.{meth.name} stores into "
                                 f"self.{attr} under a key that misses "
                                 f"{fields}"),
                        hint=(f"add {fields} to the key tuple for "
                              f"self.{attr} (or stop the value depending "
                              f"on it); see DESIGN.md §11.1")))
            exclusive.process(stmt)
            inclusive.process(stmt)
    return findings


def _cache_store(stmt: ast.stmt, extra_attrs: Sequence[str] = ()
                 ) -> Optional[Tuple[str, ast.expr, ast.expr]]:
    """(cache attr, key expr, value expr) if `stmt` assigns into a memo
    or flight map on self (or one of `extra_attrs` by exact name)."""
    if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
        return None
    target = stmt.targets[0]
    if not isinstance(target, ast.Subscript):
        return None
    container = target.value
    if not (isinstance(container, ast.Attribute)
            and isinstance(container.value, ast.Name)
            and container.value.id == "self"):
        return None
    attr = container.attr
    if "cache" not in attr and "flight" not in attr \
            and attr not in extra_attrs:
        return None
    return attr, target.slice, stmt.value


def _check_keyed_dataclass(tree: ast.Module, path: str,
                           name: str) -> List[Finding]:
    cls = find_class(tree, name)
    if cls is None:
        return [Finding(
            invariant="REPRO-C002", path=path, line=1,
            message=f"keyed dataclass {name} not found",
            hint=f"define {name} or update the analyzer configuration")]
    info = dataclass_info(cls)
    problems = []
    if not info["is_dataclass"]:
        problems.append("not a dataclass")
    if not info["frozen"]:
        problems.append("not frozen")
    if not info["eq"]:
        problems.append("eq=False")
    if problems:
        return [Finding(
            invariant="REPRO-C002", path=path, line=cls.lineno,
            message=(f"{name} participates in cache keys but is "
                     f"{' and '.join(problems)}"),
            hint=f"declare @dataclasses.dataclass(frozen=True) on {name}")]
    return []


def check_sweep_cache_keys(sweep_path: Path, *,
                           repo_root: Optional[Path] = None,
                           sweep_class: str = "Sweep",
                           point_class: str = "SweepPoint") -> List[Finding]:
    """C001/C002 over the sweep module's memo and flight stores."""
    path = _rel(sweep_path, repo_root)
    tree = parse_module(sweep_path)
    findings = _check_keyed_dataclass(tree, path, point_class)
    cls = find_class(tree, sweep_class)
    if cls is None:
        findings.append(Finding(
            invariant="REPRO-C001", path=path, line=1,
            message=f"sweep class {sweep_class} not found",
            hint="update the analyzer configuration"))
        return findings
    findings += _class_store_findings(cls, path, point_class)
    return findings


def check_timing_signature_coverage(
        timing_path: Path, sweep_path: Path, *,
        repo_root: Optional[Path] = None,
        point_class: str = "SweepPoint",
        functions: Sequence[str] = _TIMING_PUBLIC_KEYED) -> List[Finding]:
    """C003: every keyable timing-model parameter has a SweepPoint field.

    This is the other direction of completeness: C001 proves the key
    covers what flows in *today*; C003 proves a newly added model axis
    cannot exist without a sweep-layer field (and therefore, via C001, a
    key slot) to carry it.
    """
    timing_rel = _rel(timing_path, repo_root)
    timing_tree = parse_module(timing_path)
    sweep_tree = parse_module(sweep_path)
    point = find_class(sweep_tree, point_class)
    fields = set(dataclass_info(point)["fields"]) if point else set()

    findings: List[Finding] = []
    for fn in timing_tree.body:
        if not isinstance(fn, ast.FunctionDef) or fn.name not in functions:
            continue
        keyed = [a.arg for a in fn.args.kwonlyargs]
        defaulted = fn.args.args[len(fn.args.args) - len(fn.args.defaults):]
        keyed += [a.arg for a in defaulted]
        for param in keyed:
            if param in _EXEMPT_PARAMS or param in fields:
                continue
            derived = DERIVED_PARAMS.get(param)
            if derived is not None and set(derived) <= fields:
                continue
            findings.append(Finding(
                invariant="REPRO-C003", path=timing_rel, line=fn.lineno,
                message=(f"{fn.name}() parameter {param!r} has no "
                         f"{point_class} field to carry it"),
                hint=(f"add a {point_class} field (and key slot) for "
                      f"{param!r}, or register it in "
                      f"analysis.cache_keys.DERIVED_PARAMS with the "
                      f"fields it derives from")))
    return findings


def check_request_dedup(campaign_path: Path, *,
                        repo_root: Optional[Path] = None,
                        request_class: str = "ExperimentRequest",
                        service_class: str = "CampaignService",
                        response_map: str = "_responses") -> List[Finding]:
    """C002/C004 over the campaign service's request-is-the-key dedup."""
    path = _rel(campaign_path, repo_root)
    tree = parse_module(campaign_path)
    findings = _check_keyed_dataclass(tree, path, request_class)

    req_cls = find_class(tree, request_class)
    no_compare = set(dataclass_info(req_cls)["no_compare"]) if req_cls \
        else set()

    svc = find_class(tree, service_class)
    if svc is None:
        findings.append(Finding(
            invariant="REPRO-C004", path=path, line=1,
            message=f"service class {service_class} not found",
            hint="update the analyzer configuration"))
        return findings

    # The dedup key must be the whole request object, not a projection.
    store_found = False
    for meth in svc.body:
        if not isinstance(meth, ast.FunctionDef):
            continue
        params = {a.arg for a in meth.args.args if a.arg != "self"}
        for stmt in statements_in_order(meth.body):
            store = _cache_store(stmt, extra_attrs=(response_map,))
            if store is None or store[0] != response_map:
                continue
            store_found = True
            key_expr = store[1]
            if not (isinstance(key_expr, ast.Name)
                    and key_expr.id in params):
                findings.append(Finding(
                    invariant="REPRO-C004", path=path, line=stmt.lineno,
                    message=(f"{service_class}.{meth.name} keys "
                             f"self.{response_map} by a projection of the "
                             f"request instead of the request itself"),
                    hint=("key the response cache by the full "
                          f"{request_class} (it is frozen and hashable "
                          "by construction)")))
        # Fields excluded from comparison must not influence execution.
        if no_compare:
            for node in ast.walk(meth):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id in params \
                        and node.attr in no_compare:
                    findings.append(Finding(
                        invariant="REPRO-C004", path=path,
                        line=node.lineno,
                        message=(f"{request_class}.{node.attr} is "
                                 f"compare=False but "
                                 f"{service_class}.{meth.name} reads it — "
                                 f"two requests differing only in "
                                 f"{node.attr} would dedup to one "
                                 f"response"),
                        hint=(f"make {node.attr} participate in equality "
                              f"or stop the execution path depending on "
                              f"it")))
    if not store_found:
        findings.append(Finding(
            invariant="REPRO-C004", path=path, line=svc.lineno,
            message=(f"{service_class} never stores into "
                     f"self.{response_map}; the dedup path the analyzer "
                     f"guards has moved"),
            hint="update analysis.cache_keys.check_request_dedup"))

    # The oracle memo inside the service is a plain keyed cache too.
    findings += _class_store_findings(svc, path, request_class)
    return findings


def check_engine_mix_keyed(engine_mix_path: Path, *,
                           repo_root: Optional[Path] = None,
                           mix_class: str = "EngineMix") -> List[Finding]:
    """C002 over the heterogeneous-mix value type (DESIGN.md §13).

    ``EngineMix`` rides inside every contention memo/flight key (the
    ``pt.mix`` slot C001 traces through the Sweep stores), so it must be
    a frozen ``eq`` dataclass like ``SweepPoint`` itself — a mutable or
    identity-compared mix would fork cache entries between the two
    spellings of one request.
    """
    path = _rel(engine_mix_path, repo_root)
    tree = parse_module(engine_mix_path)
    return _check_keyed_dataclass(tree, path, mix_class)


def check_cache_keys(sweep_path: Path, campaign_path: Path,
                     timing_path: Path,
                     engine_mix_path: Optional[Path] = None, *,
                     repo_root: Optional[Path] = None) -> List[Finding]:
    """The whole REPRO-C family over the real tree's modules."""
    findings = check_sweep_cache_keys(sweep_path, repo_root=repo_root)
    findings += check_timing_signature_coverage(timing_path, sweep_path,
                                                repo_root=repo_root)
    findings += check_request_dedup(campaign_path, repo_root=repo_root)
    if engine_mix_path is not None:
        findings += check_engine_mix_keyed(engine_mix_path,
                                           repo_root=repo_root)
    return findings
