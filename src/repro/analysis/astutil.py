"""Shared AST machinery for the repro-lint checkers.

The load-bearing abstraction is *root dependency tracing*
(:class:`DepTracer`): within one function, every expression is reduced to
the set of **root dependencies** it transitively reads, where a root is a
function parameter (``pt``) or one of its fields (``pt.params``).  Local
assignments are followed flow-sensitively in source order (last
assignment wins), so at any statement the tracer can answer "which
``pt.*`` fields does this value depend on?" — which is exactly the
question cache-key completeness asks.

The **receiver rule** encodes the repo's channel-broadcast invariant
(DESIGN.md §4): in *receiver-exclusive* mode, a method call's bare-name
receiver (``eng`` in ``eng.evaluate_latency(...)``) contributes nothing,
because on deterministic backends engine identity must not affect the
result — its *arguments* are what flow in.  Flight-key checks run
*receiver-inclusive* (non-deterministic backends are per-engine, so the
receiver's own dependencies count).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set


def parse_module(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def find_class(tree: ast.Module, name: str) -> Optional[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def find_function(body: Sequence[ast.stmt],
                  name: str) -> Optional[ast.FunctionDef]:
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == name:
            return node  # type: ignore[return-value]
    return None


def module_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {node.name: node for node in tree.body
            if isinstance(node, ast.FunctionDef)}


def public_functions(tree: ast.Module) -> List[ast.FunctionDef]:
    return [node for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("_")]


def dataclass_info(cls: ast.ClassDef) -> Dict[str, object]:
    """Decorator + field facts for a (possible) dataclass.

    Returns ``{"is_dataclass", "frozen", "eq", "fields", "no_compare"}``
    where ``fields`` is the ordered field-name list and ``no_compare``
    the subset declared with ``field(compare=False)``.
    """
    is_dc = False
    frozen = False
    eq = True
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else (
            target.id if isinstance(target, ast.Name) else "")
        if name != "dataclass":
            continue
        is_dc = True
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                    frozen = bool(kw.value.value)
                if kw.arg == "eq" and isinstance(kw.value, ast.Constant):
                    eq = bool(kw.value.value)
    fields: List[str] = []
    no_compare: Set[str] = set()
    for stmt in cls.body:
        if not isinstance(stmt, ast.AnnAssign):
            continue
        if not isinstance(stmt.target, ast.Name):
            continue
        if isinstance(stmt.annotation, ast.Name) \
                and stmt.annotation.id == "ClassVar":
            continue
        fields.append(stmt.target.id)
        value = stmt.value
        if isinstance(value, ast.Call):
            fn = value.func
            fn_name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            if fn_name == "field":
                for kw in value.keywords:
                    if kw.arg == "compare" \
                            and isinstance(kw.value, ast.Constant) \
                            and kw.value.value is False:
                        no_compare.add(stmt.target.id)
    return {"is_dataclass": is_dc, "frozen": frozen, "eq": eq,
            "fields": fields, "no_compare": no_compare}


def statements_in_order(body: Sequence[ast.stmt]) -> Iterator[ast.stmt]:
    """Every statement, depth-first in source order (branch bodies are
    visited where they appear; good enough for the straight-line +
    guarded-branch shape of the cache methods)."""
    for stmt in body:
        yield stmt
        for attr in ("body", "orelse", "finalbody"):
            inner = getattr(stmt, attr, None)
            if inner:
                yield from statements_in_order(inner)
        for handler in getattr(stmt, "handlers", []) or []:
            yield from statements_in_order(handler.body)


class DepTracer:
    """Flow-sensitive root-dependency tracing over one function.

    ``roots`` are the parameter names whose (fields') flow is traced;
    dependency items are ``"pt"`` (the whole object) or ``"pt.field"``.
    Call :meth:`process` on each statement in source order; query an
    expression's dependencies with :meth:`deps` at any point.
    """

    def __init__(self, roots: Sequence[str], *,
                 include_receivers: bool = False):
        self.roots = set(roots)
        self.include_receivers = include_receivers
        self.env: Dict[str, Set[str]] = {}

    # -------------------------------------------------------------- query
    def deps(self, node: ast.AST, *,
             include_receivers: Optional[bool] = None) -> Set[str]:
        inc = (self.include_receivers if include_receivers is None
               else include_receivers)
        out: Set[str] = set()
        self._collect(node, out, inc)
        return out

    def _collect(self, node: ast.AST, out: Set[str], inc: bool) -> None:
        if isinstance(node, ast.Attribute):
            if isinstance(node.value, ast.Name) \
                    and node.value.id in self.roots:
                out.add(f"{node.value.id}.{node.attr}")
                return
            self._collect(node.value, out, inc)
            return
        if isinstance(node, ast.Name):
            if node.id in self.roots:
                out.add(node.id)
            elif node.id in self.env:
                out |= self.env[node.id]
            return
        if isinstance(node, ast.Call):
            # Receiver rule: a bare-name method receiver is excluded in
            # receiver-exclusive mode (channel broadcast); field-valued
            # receivers (pt.params.validate(...)) always count.
            func = node.func
            if isinstance(func, ast.Attribute) \
                    and isinstance(func.value, ast.Name):
                if inc:
                    self._collect(func.value, out, inc)
            else:
                self._collect(func, out, inc)
            for arg in node.args:
                self._collect(arg, out, inc)
            for kw in node.keywords:
                self._collect(kw.value, out, inc)
            return
        for child in ast.iter_child_nodes(node):
            self._collect(child, out, inc)

    # ------------------------------------------------------------- update
    def process(self, stmt: ast.stmt) -> None:
        """Record the bindings a statement makes (last assignment wins)."""
        if isinstance(stmt, ast.Assign):
            value_deps = self.deps(stmt.value)
            for target in stmt.targets:
                self._bind(target, value_deps)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind(stmt.target, self.deps(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name):
                prior = self.env.get(stmt.target.id, set())
                self.env[stmt.target.id] = prior | self.deps(stmt.value)

    def _bind(self, target: ast.expr, value_deps: Set[str]) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = set(value_deps)
        elif isinstance(target, (ast.Tuple, ast.List)):
            # Tuple unpack: every name carries the full RHS dependency
            # set (enabled, extra = eng.latency_config(...)).
            for elt in target.elts:
                self._bind(elt, value_deps)


def covers(required: Set[str], covered: Set[str], *,
           identity_attrs: Sequence[str] = ("name",)) -> Set[str]:
    """Required items NOT covered.

    A required item is covered by itself, by its whole root object
    (``pt`` covers ``pt.params``), or — for registry objects — by an
    identity attribute (``spec.name`` covers ``spec``, since registered
    specs are identified by name).
    """
    missing: Set[str] = set()
    for item in required:
        if item in covered:
            continue
        root = item.split(".", 1)[0]
        if root in covered:
            continue
        if any(f"{item}.{attr}" in covered for attr in identity_attrs):
            continue
        missing.add(item)
    return missing


def call_name(node: ast.Call) -> str:
    """Trailing name of the called function (``pl.BlockSpec`` →
    ``BlockSpec``)."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def int_const(node: ast.AST) -> Optional[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        inner = int_const(node.operand)
        return -inner if inner is not None else None
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.LShift):
        left, right = int_const(node.left), int_const(node.right)
        if left is not None and right is not None:
            return left << right
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        left, right = int_const(node.left), int_const(node.right)
        if left is not None and right is not None:
            return left * right
    return None
