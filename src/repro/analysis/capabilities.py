"""REPRO-B*: Backend capability contracts.

PR 4's seed bug: a backend without per-transaction timers silently
returned *read* anchors for a *write* capture.  The repo's answer is the
``supports_*`` flag + ``UnsupportedCapability`` contract — an Engine
method gated by a flag must find the backend either declaring the
capability (and implementing the method) or raising.  This checker makes
the contract structural across every ``Backend`` subclass under
``src/repro`` (sim, pallas, fault-injected, and whatever comes next).

Invariants:

* **REPRO-B001** — a gated method is implemented while the resolved flag
  says ``False`` (an undeclared capability: Engine-level gates will skip
  a working path, or worse, a later edit flips the method to a stub and
  nothing notices).
* **REPRO-B002** — the flag resolves ``True`` while the method resolves
  to the raising stub (a phantom capability: the Engine gate passes and
  the call explodes at measurement time).
* **REPRO-B003** — the flag is assigned dynamically in ``__init__`` from
  something other than another backend's same flag (an opaque
  declaration the static contract cannot vouch for; wrappers must mirror
  ``inner.supports_*``).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.analysis.astutil import parse_module
from repro.analysis.findings import Finding

# Engine-gated Backend methods and the flags that gate them
# (core/engine.py: capture_latency_list -> supports_latency,
# evaluate_contention fan-out -> supports_contention).
GATED_METHODS: Dict[str, str] = {
    "latency": "supports_latency",
    "contended_throughput": "supports_contention",
}

BASE_CLASS = "Backend"
GUARD_EXCEPTION = "UnsupportedCapability"


class _ClassFacts:
    def __init__(self, node: ast.ClassDef, path: str):
        self.node = node
        self.path = path
        self.name = node.name
        self.bases = [b.attr if isinstance(b, ast.Attribute) else b.id
                      for b in node.bases
                      if isinstance(b, (ast.Name, ast.Attribute))]
        self.methods: Dict[str, ast.FunctionDef] = {
            m.name: m for m in node.body
            if isinstance(m, ast.FunctionDef)}
        # flag -> True / False / "mirror" / "opaque"
        self.flags: Dict[str, object] = {}
        for stmt in node.body:
            if isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name) \
                    and stmt.targets[0].id.startswith("supports_") \
                    and isinstance(stmt.value, ast.Constant):
                self.flags[stmt.targets[0].id] = bool(stmt.value.value)
        init = self.methods.get("__init__")
        if init is not None:
            for stmt in ast.walk(init):
                if not (isinstance(stmt, ast.Assign)
                        and len(stmt.targets) == 1):
                    continue
                target = stmt.targets[0]
                if not (isinstance(target, ast.Attribute)
                        and isinstance(target.value, ast.Name)
                        and target.value.id == "self"
                        and target.attr.startswith("supports_")):
                    continue
                mirrored = any(
                    isinstance(n, ast.Attribute) and n.attr == target.attr
                    for n in ast.walk(stmt.value))
                self.flags[target.attr] = "mirror" if mirrored else "opaque"
                if not mirrored:
                    self.flags[target.attr + "__line"] = stmt.lineno


def _is_raising_stub(fn: ast.FunctionDef) -> bool:
    """The method's body is the contract stub: it raises the capability
    exception (docstrings and message-building assignments allowed)."""
    for stmt in fn.body:
        if isinstance(stmt, ast.Raise) and stmt.exc is not None:
            exc = stmt.exc
            name = ""
            if isinstance(exc, ast.Call):
                exc = exc.func
            if isinstance(exc, ast.Attribute):
                name = exc.attr
            elif isinstance(exc, ast.Name):
                name = exc.id
            if name == GUARD_EXCEPTION:
                return True
    return False


def _collect_backends(paths: Sequence[Path],
                      repo_root: Optional[Path]) -> Dict[str, _ClassFacts]:
    classes: Dict[str, _ClassFacts] = {}
    for path in paths:
        rel = str(path.relative_to(repo_root)) if repo_root else str(path)
        tree = parse_module(path)
        for node in tree.body:
            if isinstance(node, ast.ClassDef):
                classes[node.name] = _ClassFacts(node, rel)
    return classes


def _backend_subclasses(classes: Dict[str, _ClassFacts]
                        ) -> List[_ClassFacts]:
    def derives(name: str, seen: frozenset = frozenset()) -> bool:
        if name == BASE_CLASS:
            return True
        facts = classes.get(name)
        if facts is None or name in seen:
            return False
        return any(derives(b, seen | {name}) for b in facts.bases)

    return [facts for name, facts in classes.items()
            if name != BASE_CLASS and derives(name)]


def _resolve(classes: Dict[str, _ClassFacts], cls: _ClassFacts,
             kind: str, key: str):
    """Walk the (single-inheritance) base chain for a flag value or a
    method definition; returns (value, defining class) or (None, None).
    Instance-level flag assignments shadow class attributes, matching
    Python attribute lookup."""
    current: Optional[_ClassFacts] = cls
    while current is not None:
        table = current.flags if kind == "flag" else current.methods
        if key in table:
            return table[key], current
        nxt = None
        for base in current.bases:
            if base in classes:
                nxt = classes[base]
                break
        current = nxt
    return None, None


def check_capability_contracts(paths: Sequence[Path], *,
                               repo_root: Optional[Path] = None
                               ) -> List[Finding]:
    classes = _collect_backends(paths, repo_root)
    findings: List[Finding] = []
    for cls in sorted(_backend_subclasses(classes), key=lambda c: c.name):
        for method, flag in sorted(GATED_METHODS.items()):
            flag_value, flag_owner = _resolve(classes, cls, "flag", flag)
            method_fn, method_owner = _resolve(classes, cls, "method",
                                               method)
            implemented = (method_fn is not None
                           and not _is_raising_stub(method_fn))
            if flag_value is None:
                # No declaration anywhere on the chain (fixture-only:
                # the real Backend base declares every flag False).
                if implemented:
                    findings.append(Finding(
                        invariant="REPRO-B001", path=cls.path,
                        line=cls.node.lineno,
                        message=(f"{cls.name} implements gated method "
                                 f"{method}() but never declares "
                                 f"{flag}"),
                        hint=(f"declare {flag} = True on {cls.name} (or "
                              f"raise {GUARD_EXCEPTION} from "
                              f"{method}())")))
                continue
            if flag_value == "opaque":
                line = cls.flags.get(flag + "__line", cls.node.lineno)
                findings.append(Finding(
                    invariant="REPRO-B003", path=cls.path,
                    line=int(line),  # type: ignore[arg-type]
                    message=(f"{cls.name} assigns {flag} dynamically "
                             f"from something other than a wrapped "
                             f"backend's {flag}"),
                    hint=(f"mirror the inner backend "
                          f"(self.{flag} = inner.{flag}) or declare a "
                          f"constant class attribute")))
                continue
            if flag_value == "mirror":
                # Wrapper contract: the flag tracks the wrapped backend,
                # so the wrapper must forward the method (a raising stub
                # under a mirrored-True flag is B002-equivalent).
                if not implemented:
                    findings.append(Finding(
                        invariant="REPRO-B002", path=cls.path,
                        line=cls.node.lineno,
                        message=(f"{cls.name} mirrors {flag} from its "
                                 f"inner backend but {method}() does "
                                 f"not delegate — a capable inner "
                                 f"backend would still raise"),
                        hint=f"delegate {method}() to the inner backend"))
                continue
            if implemented and flag_value is False:
                findings.append(Finding(
                    invariant="REPRO-B001", path=cls.path,
                    line=(method_fn.lineno
                          if method_owner is cls else cls.node.lineno),
                    message=(f"{cls.name}.{method}() is implemented but "
                             f"{flag} resolves False (declared on "
                             f"{flag_owner.name}) — Engine gates will "
                             f"skip a working path"),
                    hint=f"declare {flag} = True on {cls.name}"))
            elif not implemented and flag_value is True:
                findings.append(Finding(
                    invariant="REPRO-B002", path=cls.path,
                    line=cls.node.lineno,
                    message=(f"{cls.name} declares {flag} = True but "
                             f"{method}() resolves to the "
                             f"{GUARD_EXCEPTION} stub"
                             + (f" on {method_owner.name}"
                                if method_owner and method_owner is not cls
                                else "")),
                    hint=(f"implement {method}() or declare "
                          f"{flag} = False")))
    return findings
