"""REPRO-O*: loop-oracle and parity-test coverage of the timing model.

PR 1's contract: the vectorized model in ``core/timing_model.py`` is only
trusted because ``core/_timing_reference.py`` keeps the original
per-transaction loop implementation and a parity test pins them together
(bit-exact for serial latencies, 1e-9 for throughput).  A public model
function without an oracle — or an oracle nobody tests against — is
exactly how vectorization drift ships silently.

Invariants:

* **REPRO-O001** — a public ``timing_model`` function has no loop oracle
  in ``_timing_reference.py`` (per the ORACLE_EQUIVALENTS map below).
* **REPRO-O002** — an (function, oracle) pair has no parity test that
  references both the vectorized and the reference implementation.

``serial_latencies`` is one vectorized entry point with three oracles
(read, write, contended — the reference keeps per-direction loops), so
deleting *any one* reference oracle fails the pass.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import find_class, parse_module, public_functions
from repro.analysis.findings import Finding

# vectorized public function -> reference oracles that must ALL exist.
ORACLE_EQUIVALENTS: Dict[str, Tuple[str, ...]] = {
    "throughput": ("throughput",),
    "contended_throughput": ("contended_throughput",),
    "contended_throughput_mix": ("contended_throughput_mix",),
    "serial_latencies": ("serial_read_latencies", "serial_write_latencies",
                         "serial_contended_latencies"),
    "serial_read_latencies": ("serial_read_latencies",),
}

# vectorized names a parity test may call to exercise a public function
# (serial_latencies is usually reached through its read wrapper).
VEC_ALIASES: Dict[str, Tuple[str, ...]] = {
    "serial_latencies": ("serial_latencies", "serial_read_latencies"),
}

# Public model functions that legitimately have no loop oracle, with the
# reason (surfaced in the finding if the exemption goes stale).
EXEMPT_PUBLIC: Dict[str, str] = {
    "refresh_interval_estimate":
        "post-processing estimator over an existing LatencyTrace; it has "
        "no vectorized/loop split (direct unit tests cover it)",
}


def _module_alias(tree: ast.Module, module_suffix: str) -> Optional[str]:
    """The local name a test binds `repro.core.<module_suffix>` to."""
    for node in tree.body:
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name == module_suffix \
                        or alias.name.endswith("." + module_suffix):
                    return alias.asname or alias.name
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.endswith("." + module_suffix):
                    return alias.asname or alias.name.split(".")[0]
    return None


def _attr_uses(fn: ast.FunctionDef, owner: str) -> Set[str]:
    return {node.attr for node in ast.walk(fn)
            if isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == owner}


def check_oracle_parity(timing_path: Path, reference_path: Path,
                        parity_test_path: Path, *,
                        repo_root: Optional[Path] = None) -> List[Finding]:
    def rel(p: Path) -> str:
        if repo_root is not None:
            try:
                return str(p.relative_to(repo_root))
            except ValueError:
                pass
        return str(p)

    timing_tree = parse_module(timing_path)
    reference_tree = parse_module(reference_path)
    test_tree = parse_module(parity_test_path)

    oracles = {fn.name: fn for fn in reference_tree.body
               if isinstance(fn, ast.FunctionDef)}
    findings: List[Finding] = []

    vec_alias = _module_alias(test_tree, "timing_model")
    ref_alias = _module_alias(test_tree, "_timing_reference")
    if vec_alias is None or ref_alias is None:
        findings.append(Finding(
            invariant="REPRO-O002", path=rel(parity_test_path), line=1,
            message=("parity test module does not import both "
                     "timing_model and _timing_reference"),
            hint="import both modules so parity tests can pin them"))
        return findings

    # (vec attr set, ref attr set) per test function.
    test_uses = [( _attr_uses(fn, vec_alias), _attr_uses(fn, ref_alias))
                 for fn in ast.walk(test_tree)
                 if isinstance(fn, ast.FunctionDef)
                 and fn.name.startswith("test_")]

    for fn in public_functions(timing_tree):
        name = fn.name
        if name in EXEMPT_PUBLIC:
            continue
        required = ORACLE_EQUIVALENTS.get(name)
        if required is None:
            findings.append(Finding(
                invariant="REPRO-O001", path=rel(timing_path),
                line=fn.lineno,
                message=(f"public timing-model function {name}() has no "
                         f"registered loop oracle"),
                hint=("add the loop implementation to "
                      "_timing_reference.py and map it in "
                      "analysis.oracle_parity.ORACLE_EQUIVALENTS (or "
                      "record an exemption with its reason)")))
            continue
        vec_names = set(VEC_ALIASES.get(name, (name,)))
        for oracle in required:
            oracle_fn = oracles.get(oracle)
            if oracle_fn is None:
                findings.append(Finding(
                    invariant="REPRO-O001", path=rel(reference_path),
                    line=1,
                    message=(f"loop oracle {oracle}() for "
                             f"timing_model.{name}() is missing from the "
                             f"reference module"),
                    hint=(f"restore {oracle}() in _timing_reference.py — "
                          f"the vectorized path is untrusted without "
                          f"it")))
                continue
            hit = any(vec_names & vec and oracle in ref
                      for vec, ref in test_uses)
            if not hit:
                findings.append(Finding(
                    invariant="REPRO-O002", path=rel(parity_test_path),
                    line=1,
                    message=(f"no parity test references both "
                             f"timing_model.{name}() and reference "
                             f"{oracle}()"),
                    hint=(f"add a test calling {vec_alias}."
                          f"{sorted(vec_names)[0]} and {ref_alias}."
                          f"{oracle} on the same inputs")))

    # Exemptions must stay real: an exempt name that disappears from the
    # module means the exemption table is stale.
    timing_names = {fn.name for fn in public_functions(timing_tree)}
    for name, reason in EXEMPT_PUBLIC.items():
        if name not in timing_names:
            findings.append(Finding(
                invariant="REPRO-O001", path=rel(timing_path), line=1,
                message=(f"oracle exemption for {name}() is stale — the "
                         f"function no longer exists (exempt because: "
                         f"{reason})"),
                hint="drop the entry from EXEMPT_PUBLIC"))
    return findings


# ---------------------------------------------------------------------------
# REPRO-O003/O004 — the JAX tier of the three-implementation tower.
#
# The grid port in ``core/timing_jax.py`` is only trusted because every
# public function names its NumPy mid-level oracle (the timing_model
# function the differential harness pins it against within
# ``timing_jax.REL_TOLERANCE``), and because that pair actually appears in
# ``tests/core/test_timing_differential.py``.  The grid entry points
# (`evaluate_points`, `evaluate_grid`) answer to ``contended_throughput``:
# a grid lane IS one contended-throughput evaluation, recombined over
# placements.
# ---------------------------------------------------------------------------

# public timing_jax function -> the timing_model counterpart it must be
# differentially tested against.
JAX_EQUIVALENTS: Dict[str, str] = {
    "throughput": "throughput",
    "contended_throughput": "contended_throughput",
    "contended_throughput_mix": "contended_throughput_mix",
    "evaluate_points": "contended_throughput",
    "evaluate_grid": "contended_throughput",
}

# Public timing_jax names that legitimately need no NumPy counterpart,
# with the reason (surfaced if the exemption goes stale).
JAX_EXEMPT: Dict[str, str] = {}


def _function_attr_uses(tree: ast.Module, owner: str) -> Dict[str, Set[str]]:
    """attr uses of `owner` per module-level function, with one level of
    local helper calls folded in (differential tests route shared
    assertions through module helpers)."""
    fns = {fn.name: fn for fn in tree.body
           if isinstance(fn, ast.FunctionDef)}
    direct = {name: _attr_uses(fn, owner) for name, fn in fns.items()}
    calls = {name: {node.func.id for node in ast.walk(fn)
                    if isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)}
             for name, fn in fns.items()}
    # Two folding rounds cover helper-calls-helper chains.
    for _ in range(2):
        for name in fns:
            for callee in calls[name]:
                if callee in direct:
                    direct[name] = direct[name] | direct[callee]
    return direct


def check_jax_parity(jax_path: Path, timing_path: Path,
                     differential_test_path: Path, *,
                     repo_root: Optional[Path] = None) -> List[Finding]:
    def rel(p: Path) -> str:
        if repo_root is not None:
            try:
                return str(p.relative_to(repo_root))
            except ValueError:
                pass
        return str(p)

    jax_tree = parse_module(jax_path)
    timing_tree = parse_module(timing_path)
    test_tree = parse_module(differential_test_path)

    timing_names = {fn.name for fn in public_functions(timing_tree)}
    findings: List[Finding] = []

    jax_alias = _module_alias(test_tree, "timing_jax")
    vec_alias = _module_alias(test_tree, "timing_model")
    if jax_alias is None or vec_alias is None:
        findings.append(Finding(
            invariant="REPRO-O004", path=rel(differential_test_path),
            line=1,
            message=("differential test module does not import both "
                     "timing_jax and timing_model"),
            hint="import both modules so differential tests can pin them"))
        return findings

    jax_uses = _function_attr_uses(test_tree, jax_alias)
    vec_uses = _function_attr_uses(test_tree, vec_alias)
    test_pairs = [(jax_uses[name], vec_uses[name])
                  for name in jax_uses if name.startswith("test_")]

    for fn in public_functions(jax_tree):
        name = fn.name
        if name in JAX_EXEMPT:
            continue
        counterpart = JAX_EQUIVALENTS.get(name)
        if counterpart is None:
            findings.append(Finding(
                invariant="REPRO-O003", path=rel(jax_path), line=fn.lineno,
                message=(f"public timing_jax function {name}() names no "
                         f"NumPy counterpart"),
                hint=("map it to its timing_model oracle in "
                      "analysis.oracle_parity.JAX_EQUIVALENTS (or record "
                      "an exemption with its reason)")))
            continue
        if counterpart not in timing_names:
            findings.append(Finding(
                invariant="REPRO-O003", path=rel(timing_path), line=1,
                message=(f"NumPy counterpart {counterpart}() for "
                         f"timing_jax.{name}() is not a public "
                         f"timing_model function"),
                hint="fix the JAX_EQUIVALENTS mapping"))
            continue
        hit = any(name in jax and counterpart in vec
                  for jax, vec in test_pairs)
        if not hit:
            findings.append(Finding(
                invariant="REPRO-O004", path=rel(differential_test_path),
                line=1,
                message=(f"no differential test references both "
                         f"timing_jax.{name}() and "
                         f"timing_model.{counterpart}()"),
                hint=(f"add a test calling {jax_alias}.{name} and "
                      f"{vec_alias}.{counterpart} on the same inputs")))

    jax_names = {fn.name for fn in public_functions(jax_tree)}
    for name, reason in JAX_EXEMPT.items():
        if name not in jax_names:
            findings.append(Finding(
                invariant="REPRO-O003", path=rel(jax_path), line=1,
                message=(f"JAX parity exemption for {name}() is stale — "
                         f"the function no longer exists (exempt because: "
                         f"{reason})"),
                hint="drop the entry from JAX_EXEMPT"))
    return findings


# ---------------------------------------------------------------------------
# REPRO-O005 — envelope-math coverage of the measured roofline.
#
# ``core/roofline_empirical.py`` is pure reduction math (no loop-oracle
# split to pin), so its trust story is a designated coverage tier
# instead: every public module-level function, and every public method
# of ``RooflineEnvelope``, must be exercised by some test function of
# the envelope test module.  Untested closed-form roofline math is how
# a wrong knee ships in a report nobody can falsify.
# ---------------------------------------------------------------------------

# Public envelope names that legitimately need no coverage in the
# designated test module, with the reason (surfaced if stale).
ENVELOPE_EXEMPT: Dict[str, str] = {}

ENVELOPE_CLASS = "RooflineEnvelope"


def check_envelope_coverage(envelope_path: Path, coverage_test_path: Path, *,
                            repo_root: Optional[Path] = None
                            ) -> List[Finding]:
    def rel(p: Path) -> str:
        if repo_root is not None:
            try:
                return str(p.relative_to(repo_root))
            except ValueError:
                pass
        return str(p)

    env_tree = parse_module(envelope_path)
    test_tree = parse_module(coverage_test_path)
    findings: List[Finding] = []

    required: Dict[str, int] = {
        fn.name: fn.lineno for fn in public_functions(env_tree)}
    env_cls = find_class(env_tree, ENVELOPE_CLASS)
    if env_cls is None:
        findings.append(Finding(
            invariant="REPRO-O005", path=rel(envelope_path), line=1,
            message=(f"envelope class {ENVELOPE_CLASS} not found in the "
                     f"roofline module"),
            hint="keep the public envelope dataclass where the analyzer "
                 "can see it"))
        return findings
    for node in env_cls.body:
        if isinstance(node, ast.FunctionDef) \
                and not node.name.startswith("_"):
            required[node.name] = node.lineno

    # Anything a test function touches counts: bare names (from-imports)
    # and attribute access through module aliases or envelope instances.
    used: Set[str] = set()
    for fn in ast.walk(test_tree):
        if not (isinstance(fn, ast.FunctionDef)
                and fn.name.startswith("test_")):
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute):
                used.add(node.attr)
            elif isinstance(node, ast.Name):
                used.add(node.id)

    for name, lineno in sorted(required.items(), key=lambda t: t[1]):
        if name in ENVELOPE_EXEMPT or name in used:
            continue
        findings.append(Finding(
            invariant="REPRO-O005", path=rel(envelope_path), line=lineno,
            message=(f"public envelope function/method {name}() is not "
                     f"referenced by any test in "
                     f"{rel(coverage_test_path)}"),
            hint=(f"exercise {name}() in the envelope coverage module (or "
                  f"record an exemption with its reason in "
                  f"analysis.oracle_parity.ENVELOPE_EXEMPT)")))

    for name, reason in ENVELOPE_EXEMPT.items():
        if name not in required:
            findings.append(Finding(
                invariant="REPRO-O005", path=rel(envelope_path), line=1,
                message=(f"envelope coverage exemption for {name}() is "
                         f"stale — the name no longer exists (exempt "
                         f"because: {reason})"),
                hint="drop the entry from ENVELOPE_EXEMPT"))
    return findings
