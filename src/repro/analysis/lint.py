"""repro-lint CLI: run every invariant family against the repo tree.

Usage (CI runs this before the test matrix)::

    python -m repro.analysis.lint --baseline analysis_baseline.json

Exit status is non-zero on any finding not in the baseline (*new*
violations) **and** on any baseline entry no longer reproduced (*stale*
— the baseline must shrink with the fix, keeping the pass ratchet-only).
``--write-baseline`` regenerates the file; ``--json`` dumps findings for
tooling (benchmarks/run.py --lint-report times the families through
:data:`FAMILIES`).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Callable, List, Sequence, Tuple

from repro.analysis import cache_keys, capabilities, kernel_shapes
from repro.analysis import oracle_parity
from repro.analysis.findings import (Finding, diff_baseline, load_baseline,
                                     sort_findings, to_json, write_baseline)


def default_root() -> Path:
    """Repo root, assuming the canonical src/repro/analysis layout."""
    return Path(__file__).resolve().parents[3]


def _run_cache_keys(root: Path) -> List[Finding]:
    findings = cache_keys.check_cache_keys(
        root / "src/repro/core/sweep.py",
        root / "src/repro/service/campaign.py",
        root / "src/repro/core/timing_model.py",
        root / "src/repro/core/engine_mix.py",
        repo_root=root)
    # The layout tuner keeps its own probe-score cache; its keys must
    # cover the same contention fields as the Sweep memo.
    findings.extend(cache_keys.check_sweep_cache_keys(
        root / "src/repro/core/autotune.py", repo_root=root,
        sweep_class="LayoutTuner", point_class="LayoutConfig"))
    return findings


def _run_oracle_parity(root: Path) -> List[Finding]:
    findings = oracle_parity.check_oracle_parity(
        root / "src/repro/core/timing_model.py",
        root / "src/repro/core/_timing_reference.py",
        root / "tests/core/test_timing_parity.py",
        repo_root=root)
    findings.extend(oracle_parity.check_jax_parity(
        root / "src/repro/core/timing_jax.py",
        root / "src/repro/core/timing_model.py",
        root / "tests/core/test_timing_differential.py",
        repo_root=root))
    findings.extend(oracle_parity.check_envelope_coverage(
        root / "src/repro/core/roofline_empirical.py",
        root / "tests/core/test_roofline_envelope.py",
        repo_root=root))
    return findings


def _run_capabilities(root: Path) -> List[Finding]:
    return capabilities.check_capability_contracts(
        sorted((root / "src/repro").rglob("*.py")), repo_root=root)


def _run_kernel_shapes(root: Path) -> List[Finding]:
    return kernel_shapes.check_kernel_safety(
        root / "src/repro/kernels/ops.py",
        experiments_path=root / "src/repro/core/experiments.py",
        repo_root=root)


FAMILIES: Tuple[Tuple[str, Callable[[Path], List[Finding]]], ...] = (
    ("cache_keys", _run_cache_keys),
    ("oracle_parity", _run_oracle_parity),
    ("capabilities", _run_capabilities),
    ("kernel_shapes", _run_kernel_shapes),
)


def run_analysis(root: Path) -> List[Finding]:
    """Every family over the real tree; fails loudly if the tree moved
    out from under the analyzer's configured paths."""
    required = (
        "src/repro/core/sweep.py",
        "src/repro/core/engine_mix.py",
        "src/repro/core/timing_model.py",
        "src/repro/core/timing_jax.py",
        "src/repro/core/_timing_reference.py",
        "src/repro/service/campaign.py",
        "src/repro/kernels/ops.py",
        "src/repro/core/autotune.py",
        "src/repro/core/roofline_empirical.py",
        "tests/core/test_timing_parity.py",
        "tests/core/test_timing_differential.py",
        "tests/core/test_roofline_envelope.py",
    )
    missing = [rel for rel in required if not (root / rel).exists()]
    if missing:
        raise FileNotFoundError(
            f"repro-lint: analyzed files missing under {root}: {missing} "
            f"(moved files must be re-pointed in repro.analysis.lint)")
    findings: List[Finding] = []
    for _, runner in FAMILIES:
        findings.extend(runner(root))
    return sort_findings(findings)


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST-driven invariant analysis (DESIGN.md §11)")
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root (default: inferred from layout)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help="ratchet baseline JSON to compare against")
    parser.add_argument("--write-baseline", action="store_true",
                        help="rewrite the baseline from current findings")
    parser.add_argument("--json", type=Path, default=None,
                        help="dump full findings JSON to this path")
    args = parser.parse_args(argv)

    root = (args.root or default_root()).resolve()
    findings = run_analysis(root)

    if args.json is not None:
        args.json.write_text(json.dumps(to_json(findings), indent=2,
                                        sort_keys=True) + "\n")

    if args.write_baseline:
        if args.baseline is None:
            parser.error("--write-baseline requires --baseline")
        write_baseline(args.baseline, findings)
        print(f"repro-lint: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    if args.baseline is not None:
        diff = diff_baseline(findings, load_baseline(args.baseline))
        for f in diff.new:
            print(f.render())
        for key in diff.stale:
            print(f"{key[1]}: stale baseline entry {key[0]} "
                  f"({key[2]!r}) — the violation is fixed; remove it "
                  f"from {args.baseline}")
        status = "clean" if diff.clean else (
            f"{len(diff.new)} new, {len(diff.stale)} stale")
        print(f"repro-lint: {len(findings)} finding(s), baseline "
              f"{args.baseline}: {status}")
        return 0 if diff.clean else 1

    for f in findings:
        print(f.render())
    print(f"repro-lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
