"""Findings and the ratchet baseline for repro-lint.

A :class:`Finding` is one invariant violation at one source location.  Its
*identity* for baseline purposes is ``(invariant, path, message)`` — line
numbers are deliberately excluded so unrelated edits that shift code do
not churn the committed baseline.

The baseline file (``analysis_baseline.json`` at the repo root) makes the
pass ratchet-only: CI fails on any finding not in the baseline (*new*
violations) and on any baseline entry no longer found (*stale* entries —
the fix must remove them, so the ratchet can only tighten).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation.

    ``invariant`` is the stable ID (e.g. ``REPRO-C001``), ``path`` is
    repo-relative, ``message`` states the violation, ``hint`` says how to
    fix it.  ``line`` is 1-based and informational only (not part of the
    baseline identity).
    """

    invariant: str
    path: str
    line: int
    message: str
    hint: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.invariant, self.path, self.message)

    def render(self) -> str:
        text = f"{self.path}:{self.line}: {self.invariant} {self.message}"
        if self.hint:
            text += f"  [fix: {self.hint}]"
        return text


def sort_findings(findings: Sequence[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.invariant,
                                           f.message))


def to_json(findings: Sequence[Finding]) -> Dict[str, object]:
    return {
        "version": BASELINE_VERSION,
        "findings": [dataclasses.asdict(f) for f in sort_findings(findings)],
    }


def write_baseline(path: Path, findings: Sequence[Finding]) -> None:
    payload = to_json(findings)
    # Identity only: drop line/hint so mechanical edits don't churn it.
    for entry in payload["findings"]:  # type: ignore[union-attr]
        entry.pop("line", None)
        entry.pop("hint", None)
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")


def load_baseline(path: Path) -> List[Tuple[str, str, str]]:
    """Baseline identities; a missing file is an empty baseline."""
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    version = data.get("version")
    if version != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path} has version {version!r}, expected "
            f"{BASELINE_VERSION}")
    out: List[Tuple[str, str, str]] = []
    for entry in data.get("findings", []):
        out.append((entry["invariant"], entry["path"], entry["message"]))
    return out


@dataclasses.dataclass(frozen=True)
class BaselineDiff:
    """New findings (not in baseline) and stale identities (in the
    baseline but no longer found — must be removed to keep the ratchet
    tight)."""

    new: Tuple[Finding, ...]
    stale: Tuple[Tuple[str, str, str], ...]

    @property
    def clean(self) -> bool:
        return not self.new and not self.stale


def diff_baseline(findings: Sequence[Finding],
                  baseline: Sequence[Tuple[str, str, str]]) -> BaselineDiff:
    base = set(baseline)
    found = {f.key for f in findings}
    new = tuple(f for f in sort_findings(findings) if f.key not in base)
    stale = tuple(sorted(k for k in base if k not in found))
    return BaselineDiff(new=new, stale=stale)
