"""REPRO-K*: pallas kernel shape, operand and index-arithmetic safety.

The RST kernels are parameterized through an int32 scalar-prefetch
operand consumed by BlockSpec index maps (rst_read: ``int32[4]``,
rst_contend: ``int32[6]``).  Three things can go quietly wrong before a
kernel ever runs on hardware, and all three are statically decidable:

* **REPRO-K001** — an index map (or kernel body) subscripts the scalar
  operand past the length its ops.py builder packs: ``params_ref[k]``
  with ``k >= len(operand)``.
* **REPRO-K002** — index-map arithmetic can overflow int32 at the
  registered table bounds (the index maps compute ``base + k*wset +
  (t*stride) % wset`` in int32; at the registry's Fig. 7/8 ceilings the
  raw product ``t*stride`` exceeds 2**31) and the operand builder has no
  host-side guard rejecting such configurations before launch.
* **REPRO-K003** — the documented operand dtype shape (``int32[N]`` in a
  kernel wrapper or builder docstring) drifts from the length the
  builder actually packs.
* **REPRO-K004** — the working-buffer builder ignores the RST base
  address ``A``: index maps address from ``base_block`` upward, so a
  buffer sized only by ``num_engines * W`` is out of bounds whenever
  ``A != 0``.

Bounds come from a static scan of the experiment registry
(``core/experiments.py`` keyword/dict literals for the n/w/s/a/engine
axes) with documented floors — the Fig. 7 256 MiB window, the Fig. 8
2e5-transaction stream, the 32-port switch topology — and the smallest
supported tile (``SUBLANE * LANE`` int8 bytes, parsed from
rst_read.py).  A conservative bound is fine: the guard the checker
demands (REPRO-K002) validates the *actual* operand at pack time.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (call_name, int_const, module_functions,
                                    parse_module)
from repro.analysis.findings import Finding

# Registry axes scanned for bounds, with documented floors (used when the
# registry scan finds smaller values — Fig. 7 windows, Fig. 8 streams,
# the full 32-port topology plus headroom).
AXIS_FLOORS: Dict[str, int] = {
    "n": 1 << 18,
    "w": 1 << 28,
    "s": 1 << 28,
    "a": 1 << 28,
    "num_engines": 64,
}

INT32_MAX = 2 ** 31 - 1
_MIN_ITEMSIZE = 1          # int8 — smallest dtype a tile can carry
_SCALAR_OPERAND = "params_ref"
_TABLE_OPERAND = "table_ref"
_GUARD_PATTERN = re.compile(r"int32")
_DOC_SHAPE = re.compile(r"int32\[(\d+)\]")


def _rel(path: Path, root: Optional[Path]) -> str:
    if root is not None:
        try:
            return str(path.relative_to(root))
        except ValueError:
            pass
    return str(path)


# ----------------------------------------------------------- bounds scan
def registry_bounds(experiments_path: Optional[Path]) -> Dict[str, int]:
    bounds = dict(AXIS_FLOORS)
    if experiments_path is None or not experiments_path.exists():
        return bounds
    tree = parse_module(experiments_path)
    for node in ast.walk(tree):
        pairs: List[Tuple[str, ast.expr]] = []
        if isinstance(node, ast.Call):
            pairs = [(kw.arg, kw.value) for kw in node.keywords if kw.arg]
        elif isinstance(node, ast.Dict):
            for key, value in zip(node.keys, node.values):
                if isinstance(key, ast.Constant) \
                        and isinstance(key.value, str):
                    pairs.append((key.value, value))
        for name, value in pairs:
            if name not in bounds:
                continue
            vals = [int_const(value)]
            if isinstance(value, (ast.List, ast.Tuple)):
                vals = [int_const(e) for e in value.elts]
            for v in vals:
                if v is not None and v > bounds[name]:
                    bounds[name] = v
    return bounds


def _lane_sublane(kernel_tree: ast.Module) -> Tuple[int, int]:
    lane, sublane = 128, 8
    for node in kernel_tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            value = int_const(node.value)
            if value is None:
                continue
            if node.targets[0].id == "LANE":
                lane = value
            elif node.targets[0].id == "SUBLANE":
                sublane = value
    return lane, sublane


# ------------------------------------------------------- operand packing
def _local_assign(fn: ast.FunctionDef, name: str) -> Optional[ast.expr]:
    found = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for target in node.targets:
                if isinstance(target, ast.Name) and target.id == name:
                    found = node.value
    return found


def _expr_length(expr: ast.expr, fn: ast.FunctionDef,
                 fns: Dict[str, ast.FunctionDef]) -> Optional[int]:
    if isinstance(expr, ast.Call):
        name = call_name(expr)
        if name == "array" and expr.args \
                and isinstance(expr.args[0], (ast.List, ast.Tuple)):
            return len(expr.args[0].elts)
        if name == "concatenate" and expr.args \
                and isinstance(expr.args[0], (ast.List, ast.Tuple)):
            total = 0
            for elt in expr.args[0].elts:
                part = _expr_length(elt, fn, fns)
                if part is None:
                    return None
                total += part
            return total
        if isinstance(expr.func, ast.Name) and expr.func.id in fns:
            return _builder_length(fns[expr.func.id], fns)
    if isinstance(expr, ast.Name):
        defining = _local_assign(fn, expr.id)
        if defining is not None:
            return _expr_length(defining, fn, fns)
    return None


def _builder_length(fn: ast.FunctionDef,
                    fns: Dict[str, ast.FunctionDef]) -> Optional[int]:
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            length = _expr_length(node.value, fn, fns)
            if length is not None:
                return length
    return None


def _calls_guard(fn: ast.FunctionDef,
                 fns: Dict[str, ast.FunctionDef],
                 seen: Optional[Set[str]] = None) -> bool:
    """Call in `fn` (or a local helper it calls — the per-entry mix
    builders guard each table row inside `_mix_block_rows`) to a
    host-side int32-range guard."""
    seen = seen or set()
    seen.add(fn.name)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if _GUARD_PATTERN.search(call_name(node)):
            return True
        if isinstance(node.func, ast.Name) and node.func.id in fns \
                and node.func.id not in seen \
                and _calls_guard(fns[node.func.id], fns, seen):
            return True
    return False


def _table_row_width(fn: ast.FunctionDef,
                     fns: Dict[str, ast.FunctionDef]) -> Optional[int]:
    """Statically-evident row width of a *table* operand builder.

    A mix builder packs ``int32[rows, width]`` where the row count is
    runtime (one row per engine) but every row is a literal list of the
    same width — the header row plus the per-engine rows appended by its
    helpers.  Returns that width when every >= 2-element flat list
    literal in the builder (and the local helpers it calls) agrees on
    one length, else None (ambiguous — surfaced as K001)."""
    widths: Set[int] = set()
    seen = {fn.name}
    stack = [fn]
    while stack:
        cur = stack.pop()
        for node in ast.walk(cur):
            if isinstance(node, ast.List) and len(node.elts) >= 2 \
                    and not any(isinstance(e, (ast.List, ast.Starred))
                                for e in node.elts):
                widths.add(len(node.elts))
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in fns and node.func.id not in seen:
                seen.add(node.func.id)
                stack.append(fns[node.func.id])
    return widths.pop() if len(widths) == 1 else None


def _max_table_column(tree: ast.Module) -> Tuple[int, int]:
    """(max constant column subscript on the table operand, its line):
    ``table_ref[row, col]`` reads with a constant col."""
    best, line = -1, 1
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == _TABLE_OPERAND \
                and isinstance(node.slice, ast.Tuple) \
                and len(node.slice.elts) == 2:
            idx = int_const(node.slice.elts[1])
            if idx is not None and idx > best:
                best, line = idx, node.lineno
    return best, line


def _kernel_feeds(ops_tree: ast.Module,
                  fns: Dict[str, ast.FunctionDef]) -> Dict[str, Set[str]]:
    """kernel callee name -> operand builder names whose result is the
    kernel's first (scalar-prefetch) argument."""
    builders = {name for name in fns if name.endswith("operand")}
    feeds: Dict[str, Set[str]] = {}
    for fn in fns.values():
        local_builder: Dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Call) \
                    and isinstance(node.value.func, ast.Name) \
                    and node.value.func.id in builders:
                local_builder[node.targets[0].id] = node.value.func.id
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name) and node.args):
                continue
            first = node.args[0]
            if isinstance(first, ast.Name) \
                    and first.id in local_builder:
                feeds.setdefault(node.func.id, set()).add(
                    local_builder[first.id])
    return feeds


def _kernel_modules(ops_tree: ast.Module,
                    ops_path: Path) -> Dict[str, Path]:
    """imported kernel name -> kernel module path (same package dir)."""
    out: Dict[str, Path] = {}
    for node in ops_tree.body:
        if isinstance(node, ast.ImportFrom) and node.module \
                and ".kernels." in f".{node.module}.":
            mod_file = ops_path.parent / (node.module.rsplit(".", 1)[-1]
                                          + ".py")
            if mod_file == ops_path or not mod_file.exists():
                continue
            for alias in node.names:
                out[alias.asname or alias.name] = mod_file
    return out


def _max_operand_index(tree: ast.Module) -> Tuple[int, int]:
    """(max constant subscript on the scalar operand, its line)."""
    best, line = -1, 1
    for node in ast.walk(tree):
        if isinstance(node, ast.Subscript) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == _SCALAR_OPERAND:
            idx = int_const(node.slice)
            if idx is not None and idx > best:
                best, line = idx, node.lineno
    return best, line


def _doc_shapes(tree: ast.Module) -> List[Tuple[str, int, int]]:
    """(function name, declared operand length, line) per docstring that
    declares an int32[N] scalar operand."""
    out = []
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            doc = ast.get_docstring(node) or ""
            for match in _DOC_SHAPE.finditer(doc):
                out.append((node.name, int(match.group(1)), node.lineno))
    return out


# ------------------------------------------------------------ the check
def check_kernel_safety(ops_path: Path, *,
                        experiments_path: Optional[Path] = None,
                        kernel_paths: Optional[Dict[str, Path]] = None,
                        buffer_builder: str = "make_working_buffer",
                        repo_root: Optional[Path] = None) -> List[Finding]:
    ops_rel = _rel(ops_path, repo_root)
    ops_tree = parse_module(ops_path)
    fns = module_functions(ops_tree)
    feeds = _kernel_feeds(ops_tree, fns)
    if kernel_paths is None:
        kernel_paths = _kernel_modules(ops_tree, ops_path)
    bounds = registry_bounds(experiments_path)

    findings: List[Finding] = []

    # Worst-case index-map products at the registry bounds, using the
    # smallest supported tile (largest block counts).
    lane, sublane = 128, 8
    for path in kernel_paths.values():
        lane, sublane = _lane_sublane(parse_module(path))
        break
    tile_min = lane * sublane * _MIN_ITEMSIZE
    stride_blocks = max(bounds["s"], 1) // tile_min
    wset_blocks = max(bounds["w"], 1) // tile_min
    base_blocks = max(bounds["a"], 1) // tile_min
    worst_linear = (bounds["n"] - 1) * stride_blocks
    worst_contend = (base_blocks + bounds["num_engines"] * wset_blocks
                     + worst_linear)
    overflow_possible = max(worst_linear, worst_contend) > INT32_MAX

    checked_kernels: Set[Path] = set()
    for kernel_name, builders in sorted(feeds.items()):
        kernel_path = kernel_paths.get(kernel_name)
        if kernel_path is None:
            continue
        kernel_rel = _rel(kernel_path, repo_root)
        kernel_tree = parse_module(kernel_path)
        checked_kernels.add(kernel_path)

        lengths = {b: _builder_length(fns[b], fns) for b in builders}
        known = {b: n for b, n in lengths.items() if n is not None}
        # Builders without a flat static length may pack a per-engine
        # *table* (int32[rows, width], dynamic row count): K001 for
        # those checks the kernel's constant column reads against the
        # statically-evident row width instead.
        tables = {b: _table_row_width(fns[b], fns)
                  for b in sorted(builders - set(known))}
        for builder, width in sorted(tables.items()):
            if width is None:
                findings.append(Finding(
                    invariant="REPRO-K001", path=ops_rel,
                    line=fns[builder].lineno,
                    message=(f"operand builder {builder}() packs a shape "
                             f"the analyzer cannot resolve statically"),
                    hint=("build the operand from literal jnp.array/"
                          "jnp.concatenate lists (or same-width literal "
                          "rows) so its shape is statically evident")))
                continue
            max_col, col_line = _max_table_column(kernel_tree)
            if max_col >= width:
                findings.append(Finding(
                    invariant="REPRO-K001", path=kernel_rel, line=col_line,
                    message=(f"{kernel_name} reads {_TABLE_OPERAND}"
                             f"[*, {max_col}] but {builder}() packs rows "
                             f"of width {width}"),
                    hint=(f"widen the rows {builder}() packs (and the "
                          f"docstrings) or drop the out-of-range column "
                          f"read")))
            if overflow_possible and not _calls_guard(fns[builder], fns):
                findings.append(Finding(
                    invariant="REPRO-K002", path=ops_rel,
                    line=fns[builder].lineno,
                    message=(f"{builder}() packs table rows whose "
                             f"index-map products can exceed int32 at "
                             f"the registry bounds with no host-side "
                             f"range guard"),
                    hint=("validate each entry's (n-1)*stride_blocks and "
                          "base+wset_blocks against 2**31 before packing "
                          "(call an *int32* guard helper so the analyzer "
                          "can see it)")))
        if not known:
            continue
        operand_len = min(known.values())
        short_builder = min(known, key=lambda b: known[b])

        max_index, line = _max_operand_index(kernel_tree)
        if max_index >= operand_len:
            findings.append(Finding(
                invariant="REPRO-K001", path=kernel_rel, line=line,
                message=(f"{kernel_name} reads {_SCALAR_OPERAND}"
                         f"[{max_index}] but {short_builder}() packs "
                         f"only int32[{operand_len}]"),
                hint=(f"extend {short_builder}() (and the docstrings) or "
                      f"drop the out-of-range read")))

        for fn_name, declared, doc_line in _doc_shapes(kernel_tree):
            if declared != operand_len:
                findings.append(Finding(
                    invariant="REPRO-K003", path=kernel_rel,
                    line=doc_line,
                    message=(f"{fn_name}() documents an int32"
                             f"[{declared}] operand but "
                             f"{short_builder}() packs int32"
                             f"[{operand_len}]"),
                    hint="update the docstring or the builder together"))

        if overflow_possible:
            for builder in sorted(known):
                if not _calls_guard(fns[builder], fns):
                    findings.append(Finding(
                        invariant="REPRO-K002", path=ops_rel,
                        line=fns[builder].lineno,
                        message=(f"{builder}() packs operands whose "
                                 f"index-map products can exceed int32 "
                                 f"at the registry bounds (worst case "
                                 f"~{max(worst_linear, worst_contend):e})"
                                 f" with no host-side range guard"),
                        hint=("validate (n-1)*stride_blocks and "
                              "base+engines*wset_blocks against 2**31 "
                              "before packing (call an *int32* guard "
                              "helper so the analyzer can see it)")))

    # Builder docstrings in ops.py must match what they pack.
    for fn_name, declared, doc_line in _doc_shapes(ops_tree):
        if not fn_name.endswith("operand"):
            continue
        actual = _builder_length(fns[fn_name], fns)
        if actual is not None and actual != declared:
            findings.append(Finding(
                invariant="REPRO-K003", path=ops_rel, line=doc_line,
                message=(f"{fn_name}() documents int32[{declared}] but "
                         f"packs int32[{actual}]"),
                hint="update the docstring or the packing together"))

    # Working-buffer coverage: index maps address from base_block
    # (= A // tile) upward, so the buffer must account for p.a.
    buffer_fn = fns.get(buffer_builder)
    if buffer_fn is not None:
        reads_base = any(
            isinstance(node, ast.Attribute) and node.attr == "a"
            for node in ast.walk(buffer_fn))
        if not reads_base:
            findings.append(Finding(
                invariant="REPRO-K004", path=ops_rel,
                line=buffer_fn.lineno,
                message=(f"{buffer_builder}() sizes the buffer without "
                         f"the RST base address A — index maps address "
                         f"base_block + window blocks, so any A != 0 "
                         f"reads past the buffer"),
                hint=(f"size the buffer over p.a + num_engines * p.w "
                      f"bytes in {buffer_builder}()")))

    # A builder feeding several kernels (params_operand: read + write)
    # would otherwise report once per kernel.
    unique: Dict[Tuple[str, str, str], Finding] = {}
    for f in findings:
        unique.setdefault(f.key, f)
    return list(unique.values())
