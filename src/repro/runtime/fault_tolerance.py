"""Fault-tolerance runtime: heartbeats, straggler detection, elastic retry.

Scope note (CPU container): the *policies* here are real and unit-tested;
the failure signals are injected through `HealthSource` so the same
controller drives either simulated failures (tests, examples) or real ones
(on a cluster: jax.distributed heartbeats + XlaRuntimeError from collective
timeouts).

Design for 1000+ nodes (DESIGN.md §4):
  * deterministic stateless data (repro.data) => restart needs only
    (checkpoint, step), no data-iterator state;
  * elastic re-mesh: on node loss, the controller restores the latest
    checkpoint onto the largest usable (pods, data, model) mesh from the
    configured ladder, re-lowering the step function;
  * straggler mitigation: per-host step-time EWMA; hosts slower than
    median * threshold for `patience` consecutive steps are reported for
    eviction (the standard TPU approach — evict & re-mesh — rather than
    GPU-style backup workers, since collectives are synchronous).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class HealthSource:
    """Pluggable source of node-health signals."""

    def alive_nodes(self) -> List[int]:
        raise NotImplementedError

    def step_times(self) -> Dict[int, float]:
        """Most recent per-host step wall time (seconds)."""
        raise NotImplementedError


class SimulatedHealth(HealthSource):
    """Scripted failures/stragglers for tests and examples."""

    def __init__(self, num_nodes: int):
        self.num_nodes = num_nodes
        self._dead: set = set()
        self._slow: Dict[int, float] = {}
        self.base_step_time = 1.0

    def kill(self, node: int):
        self._dead.add(node)

    def revive(self, node: int):
        self._dead.discard(node)

    def make_slow(self, node: int, factor: float):
        self._slow[node] = factor

    def alive_nodes(self) -> List[int]:
        return [n for n in range(self.num_nodes) if n not in self._dead]

    def step_times(self) -> Dict[int, float]:
        return {n: self.base_step_time * self._slow.get(n, 1.0)
                for n in self.alive_nodes()}


@dataclasses.dataclass
class StragglerDetector:
    """EWMA-based detector: flags hosts persistently slower than the fleet."""

    threshold: float = 1.5      # x median
    patience: int = 3           # consecutive flagged steps
    alpha: float = 0.3          # EWMA smoothing

    def __post_init__(self):
        self._ewma: Dict[int, float] = {}
        self._strikes: Dict[int, int] = {}

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        """Feed one step's per-host times; returns hosts to evict.

        A strike requires BOTH the smoothed and the instantaneous time to
        exceed the threshold — a single transient blip (preemption, GC)
        decays out of the EWMA without accumulating strikes.

        Raises RuntimeError on an empty `step_times`: no reporting host
        means every node died (or the HealthSource broke), which is a
        recover/re-mesh situation — not a "median of nothing" numpy
        warning that silently turns the eviction math into NaNs.
        """
        if not step_times:
            raise RuntimeError(
                "StragglerDetector.observe got no step times: every node "
                "is dead (or the HealthSource returned nothing); recover "
                "and re-mesh before resuming straggler detection")
        for n, t in step_times.items():
            prev = self._ewma.get(n, t)
            self._ewma[n] = (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self._ewma.values())))
        med_now = float(np.median(list(step_times.values())))
        evict = []
        for n, e in self._ewma.items():
            slow_now = step_times.get(n, 0.0) > self.threshold * med_now
            if e > self.threshold * med and slow_now:
                self._strikes[n] = self._strikes.get(n, 0) + 1
            else:
                self._strikes[n] = 0
            if self._strikes[n] >= self.patience:
                evict.append(n)
        return evict

    def forget(self, node: int) -> None:
        self._ewma.pop(node, None)
        self._strikes.pop(node, None)


@dataclasses.dataclass(frozen=True)
class MeshLadder:
    """Usable mesh configurations, largest first: (pods, data, model)."""

    rungs: Tuple[Tuple[int, int, int], ...] = (
        (2, 16, 16), (1, 16, 16), (1, 8, 16), (1, 4, 16))

    def best_for(self, alive_chips: int) -> Tuple[int, int, int]:
        for rung in self.rungs:
            p, d, m = rung
            if p * d * m <= alive_chips:
                return rung
        raise RuntimeError(
            f"only {alive_chips} chips alive; below minimum rung "
            f"{self.rungs[-1]}")


@dataclasses.dataclass
class FaultTolerantLoop:
    """Drives step_fn with checkpoint/restart + elastic re-mesh policies.

    step_fn(step) -> metrics dict; raise to signal a failure.
    on_remesh(rung) re-lowers for a new topology and restores state.

    The abort budget is *windowed*: `max_failures` bounds the failures
    seen since the last sustained-progress reset, and the budget resets
    after `reset_after_clean_steps` consecutive clean steps.  A global
    (never-resetting) count would eventually abort arbitrarily long runs
    that each recovered fine — ten node losses over a month of training
    is healthy attrition, ten in quick succession is an outage.
    `failures` still reports the total (all-time) count.
    """

    step_fn: Callable[[int], Dict]
    save_fn: Callable[[int], None]
    restore_fn: Callable[[], int]            # -> step to resume from
    health: HealthSource
    ladder: MeshLadder = MeshLadder()
    on_remesh: Optional[Callable[[Tuple[int, int, int]], None]] = None
    checkpoint_every: int = 50
    max_failures: int = 10
    reset_after_clean_steps: int = 50

    def __post_init__(self):
        self.detector = StragglerDetector()
        self.failures = 0               # all-time, for reporting
        self._window_failures = 0       # since last clean-streak reset
        self._clean_streak = 0
        self.evictions: List[int] = []
        self.remesh_events: List[Tuple[int, Tuple[int, int, int]]] = []

    def run(self, start_step: int, num_steps: int) -> Dict:
        step = start_step
        history = []
        while step < start_step + num_steps:
            try:
                metrics = self.step_fn(step)
            except Exception:
                self.failures += 1
                self._window_failures += 1
                self._clean_streak = 0
                if self._window_failures > self.max_failures:
                    raise
                step = self._recover(step)
                continue
            history.append(metrics)
            self._clean_streak += 1
            if (self._clean_streak >= self.reset_after_clean_steps
                    and self._window_failures):
                self._window_failures = 0
            # Straggler policy.
            for node in self.detector.observe(self.health.step_times()):
                if node not in self.evictions:
                    self.evictions.append(node)
                    self.detector.forget(node)
            if (step + 1) % self.checkpoint_every == 0:
                self.save_fn(step)
            step += 1
        return {"steps": len(history), "failures": self.failures,
                "evictions": self.evictions,
                "remesh_events": self.remesh_events,
                "history": history}

    def _recover(self, failed_step: int) -> int:
        alive = len(self.health.alive_nodes())
        rung = self.ladder.best_for(alive * self._chips_per_node())
        if self.on_remesh is not None:
            self.on_remesh(rung)
        self.remesh_events.append((failed_step, rung))
        return self.restore_fn()

    def _chips_per_node(self) -> int:
        # v5e: 4 chips per host is typical; configurable if needed.
        return 4
