from repro.runtime.fault_tolerance import (FaultTolerantLoop, HealthSource,
                                           MeshLadder, SimulatedHealth,
                                           StragglerDetector)

__all__ = ["FaultTolerantLoop", "HealthSource", "MeshLadder",
           "SimulatedHealth", "StragglerDetector"]
