"""Benchmark harness over the declarative experiment registry.

Every paper table/figure is a registered `Experiment`
(core/experiments.py); `bench_experiments` times each one per applicable
memory spec and prints ``name,us_per_call,derived`` CSV rows.
`us_per_call` is the wall time of running the suite through the calibrated
engine model (the measurement machinery itself); `derived` carries the
headline quantity the paper reports for that artifact (each experiment's
`summarize`).  The TPU-analogue and framework-integration benches below
are not paper artifacts and stay hand-written.

With ``--json PATH`` the same rows (plus totals) are written as a
``BENCH_*.json`` perf-trajectory file so successive PRs can track the
sim-backend speedup (CI writes ``BENCH_ci.json`` on every push).
``--experiments name1,name2`` restricts the registry suite (unknown names
fail with the registered list).  ``--engines N`` replaces the contention
experiments' engine-count ladder with powers of two up to N.
``--arbitration POLICY`` / ``--burst B`` select the shared-port grant
granularity (round_robin / burst / exclusive, DESIGN.md §9) for every
experiment that exposes the axis (CI runs one burst-grant ladder —
``--engines 4 --arbitration burst --burst 8`` — on every push).
``--catalog [PATH]`` emits the registry-generated experiment-catalog
table instead of benchmarking — to stdout, or spliced into README.md's
catalog markers.

``--service`` switches to the campaign-service soak (DESIGN.md §10): a
mixed batch of duplicate-heavy experiment requests is served through
`CampaignService` against a fault-injected primary backend at each
``--fault-rate`` (comma list, default ``0,0.01,0.1``), with sim
fallback.  Each soak asserts the service invariants — zero dropped
requests, duplicates coalesced (backend executions < requests), and at
the highest non-zero rate at least one degraded (fallback) response —
and records sustained QPS per rate (``--qps-target`` makes a floor of it).
CI uploads this as ``BENCH_ci_service.json``.

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
         [--experiments NAMES] [--engines N]
         [--arbitration POLICY] [--burst B] [--catalog [PATH]]
         [--service] [--fault-rate RATES] [--qps-target QPS]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    dt = (time.perf_counter() - t0) * 1e6
    return out, dt


# Specs the registry-driven benches run over by default: the paper's
# measured pair, keeping the historical perf-trajectory row names stable.
# The modeled HBM3/DDR3 generalization targets are pinned by tier-1 tests
# and the example campaign driver instead — adding them here would suffix
# the single-spec rows (table6/fig8) and break BENCH_*.json comparability.
# Experiments that set `bench_specs` (the write/duplex family runs on all
# four registered systems) override this default per experiment.
BENCH_SPEC_NAMES = ("hbm", "ddr4")


def resolve_experiments(names):
    """Resolve a comma-separated experiment filter against the registry.

    Exits with a clear message (listing every registered name) instead of
    surfacing a traceback when a name is unknown.
    """
    from repro.core.experiments import all_experiments, get_experiment

    if not names:
        return all_experiments()
    try:
        return [get_experiment(n.strip()) for n in names.split(",")]
    except ValueError as e:
        raise SystemExit(f"benchmarks.run: {e}")


def engine_ladder(max_engines):
    """The --engines N override: powers of two up to (and including) N."""
    if max_engines < 1:
        raise SystemExit(
            f"benchmarks.run: --engines must be >= 1, got {max_engines}")
    ladder = []
    k = 1
    while k < max_engines:
        ladder.append(k)
        k *= 2
    ladder.append(max_engines)
    return tuple(ladder)


def parse_engines_arg(text):
    """Resolve the --engines value: a bare integer N (engine-count ladder)
    or a heterogeneous mix spec like '2r+1w+1d' (DESIGN.md §13).

    Returns the int for the ladder form, the validated spec string for the
    mix form; exits with the accepted grammar on anything else — the same
    UX as an unknown --experiments name.
    """
    from repro.core.engine_mix import parse_mix_spec

    if text.isdigit():
        n = int(text)
        engine_ladder(n)        # validates >= 1 up front, not per suite
        return n
    try:
        parse_mix_spec(text)
    except ValueError as e:
        raise SystemExit(f"benchmarks.run: --engines: {e}")
    return text


def bench_experiments(quick=False, experiments=None, engines=None,
                      arbitration=None, burst=None):
    """One row per (registered experiment, applicable spec).

    All grid/derive/summary logic lives on the Experiment objects
    (core/experiments.py); this harness only iterates the registry.
    Single-spec experiments (the switch suites) keep their bare row name;
    multi-spec ones are suffixed with the spec, matching the historical
    row names so BENCH_*.json trajectories stay comparable.  `engines`
    (the --engines flag) replaces the engine-count ladder of the
    contention experiments — every experiment with an "engines" option —
    when given as an int, or (as a mix spec like '2r+1w+1d') the custom
    blend of every experiment with a "custom_mix" option (the engine-mix
    family, DESIGN.md §13); `arbitration`/`burst` (--arbitration/--burst)
    select the shared-port grant granularity for every experiment
    exposing that axis.
    """
    from repro.core import spec_by_name
    from repro.core.experiments import run_experiment

    rows = []
    for exp in resolve_experiments(experiments):
        specs = [spec_by_name(n)
                 for n in (exp.bench_specs or BENCH_SPEC_NAMES)]
        available = [s for s in specs if exp.available_on(s)]
        label = exp.bench_label or exp.name
        overrides = {}
        if isinstance(engines, int) and "engines" in exp.defaults:
            overrides["engines"] = engine_ladder(engines)
        elif isinstance(engines, str) and "custom_mix" in exp.defaults:
            overrides["custom_mix"] = engines
        if arbitration is not None and "arbitration" in exp.defaults:
            overrides["arbitration"] = arbitration
            if arbitration != "burst" and "burst_beats" in exp.defaults:
                # round_robin/exclusive fix the grant size; leaving an
                # experiment's default burst_beats (e.g. the contended-
                # latency classes' 8) in place would fail validation.
                overrides["burst_beats"] = 1
        if burst is not None and "burst_beats" in exp.defaults:
            overrides["burst_beats"] = burst
        for spec in available:
            res, dt = _timed(lambda: run_experiment(
                exp, spec, quick=quick, bench=True, **overrides))
            name = label if len(available) == 1 else f"{label}_{spec.name}"
            rows.append((name, dt, exp.summary(spec, res)))
    return rows


def bench_table3_resources():
    """Table III analogue: engine 'resource' footprint on TPU = VMEM bytes
    per RST engine tile + params-register bytes (vs FPGA LUTs/BRAM)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    def run():
        tile = ops.tile_bytes(jnp.float32)                 # VMEM per burst
        regs = 2 * 32                                       # 2x256-bit regs
        return {"vmem_tile_bytes": tile, "register_bytes": regs}

    res, dt = _timed(run)
    return [("table3_resources_tpu_analogue", dt,
             f"vmem_tile_bytes={res['vmem_tile_bytes']};"
             f"register_bytes={res['register_bytes']}")]


def bench_tpu_rst_kernel(quick=False):
    """TPU-native RST engines (interpret mode): checksum-validated
    bandwidth samples for sequential vs strided traversals."""
    import jax.numpy as jnp

    from repro.core.params import RSTParams
    from repro.kernels import ops
    n = 32 if quick else 128
    rows = []
    for name, (s_mult, w_tiles) in {
        "seq": (1, 64), "strided4": (4, 64), "hammer": (64, 64),
    }.items():
        tile = ops.tile_bytes(jnp.float32)
        p = RSTParams(n=n, b=tile, s=tile * s_mult, w=tile * w_tiles)
        sample, dt = _timed(
            lambda p=p: ops.measure_read_bandwidth(p, dtype=jnp.float32))
        rows.append((f"tpu_rst_read_{name}", dt,
                     f"bytes={sample.bytes_moved};interp_gbps="
                     f"{sample.gbps:.4f}"))
    return rows


def bench_sweep_grid(quick=False):
    """Sweep planner: one batched (policy x stride x channel) campaign grid,
    exercising memoization + channel broadcast (core/sweep.py)."""
    from repro.core import HBM, RSTParams, Sweep

    strides = (64, 1024) if quick else (64, 256, 1024, 4096)
    channels = range(0, 32, 4)
    n = 1024 if quick else 4096

    def run():
        sweep = Sweep(HBM)
        sweep.add_grid(
            [RSTParams(n=n, b=64, s=s, w=0x10000000) for s in strides],
            policies=("RGBCG", "RBC", "BRC"), channels=tuple(channels))
        results = sweep.run()
        return sweep.stats, results

    (stats, results), dt = _timed(run)
    gbps = [r.value.gbps for r in results]
    return [("sweep_grid_hbm", dt,
             f"points={stats.points};evaluated={stats.evaluated};"
             f"cache_hits={stats.cache_hits};max_gbps={max(gbps):.2f}")]


def bench_grid(quick=False):
    """Grid-evaluation ladder (DESIGN.md §12): one policy x stride x op x
    engines x arbitration x placement cross-product priced four ways —
    per-point NumPy, per-point jit, one jit+vmap compiled grid, and the
    mesh-sharded grid.  The jit+vmap : per-point-NumPy ratio is the PR's
    acceptance number (>= 100x on the >= 10k-point default grid).
    """
    import jax
    from repro.core import HBM, RSTParams, get_mapping
    from repro.core import timing_jax, timing_model
    from repro.core.address_mapping import policies_for
    from repro.launch.mesh import grid_mesh

    spec = HBM
    # Long streams are where batching pays: every lane below is exactly
    # periodic (pow2 everything, no exclusive grants), so the compiled
    # grid evaluates a 2-window steady-state kernel per lane while the
    # per-point NumPy path expands all 2^17 commands.
    n = 1 << 15 if quick else 1 << 17
    nparams = 6 if quick else 18
    params = tuple(RSTParams(n=n, b=32, s=256 << (i % 6),
                             w=(256 << (i % 6)) * (1 << (i // 6)))
                   for i in range(nparams))
    axes = timing_jax.GridAxes(
        params=params,
        policies=(None,) + tuple(policies_for(spec))[:3],
        ops=("read", "write", "duplex"),
        num_engines=(1, 4) if quick else (1, 2, 4, 8),
        arbitrations=((("round_robin", 1), ("burst", 4)) if quick else
                      (("round_robin", 1), ("burst", 2), ("burst", 4),
                       ("burst", 8))),
        placements=("same_channel", "same_switch", "cross_switch"))

    # Rung 1: the uncached naive path — one host-side NumPy evaluation
    # per point, timed on an evenly-spaced sample (the full product at
    # ~ms/point is exactly what this ladder exists to retire).
    pts = axes.sweep_points()
    sample = pts[::max(1, len(pts) // (8 if quick else 24))]
    def run_numpy():
        for pt in sample:
            timing_model.contended_throughput(
                pt.params, get_mapping(spec, pt.policy), spec,
                num_engines=pt.num_engines, op=pt.op,
                arbitration=pt.arbitration, burst_beats=pt.burst_beats)
    _, numpy_us = _timed(run_numpy)
    numpy_pps = len(sample) / (numpy_us * 1e-6)
    rows = [("grid_per_point_numpy", numpy_us,
             f"sampled={len(sample)};pts_per_s={numpy_pps:.0f}")]

    # Rung 2: per-point jit — same sample through the JAX single-point
    # wrapper (one compile per shape bucket, then per-call dispatch).
    timing_jax.contended_throughput(
        sample[0].params, get_mapping(spec, sample[0].policy), spec,
        num_engines=sample[0].num_engines, op=sample[0].op,
        arbitration=sample[0].arbitration,
        burst_beats=sample[0].burst_beats)          # warm the jit cache
    def run_jit_pp():
        for pt in sample:
            timing_jax.contended_throughput(
                pt.params, get_mapping(spec, pt.policy), spec,
                num_engines=pt.num_engines, op=pt.op,
                arbitration=pt.arbitration, burst_beats=pt.burst_beats)
    _, jitpp_us = _timed(run_jit_pp)
    rows.append(("grid_jit_per_point", jitpp_us,
                 f"sampled={len(sample)};"
                 f"pts_per_s={len(sample) / (jitpp_us * 1e-6):.0f}"))

    # Rung 3: jit+vmap — the whole cross-product as one compiled program.
    cold, cold_us = _timed(lambda: timing_jax.evaluate_grid(spec, axes))
    warm, warm_us = _timed(lambda: timing_jax.evaluate_grid(spec, axes))
    vmap_pps = warm.size / (warm_us * 1e-6)
    rows.append(("grid_jit_vmap", warm_us,
                 f"points={warm.size};pts_per_s={vmap_pps:.0f};"
                 f"cold_s={cold_us * 1e-6:.2f};"
                 f"speedup_vs_numpy={vmap_pps / numpy_pps:.0f}x"))

    # Rung 4: mesh-sharded grid (1 device locally; CI forces 8 host
    # devices via XLA_FLAGS so the sharded rung exercises real sharding).
    mesh = grid_mesh()
    timing_jax.evaluate_grid(spec, axes, mesh=mesh)   # compile + place
    shard, shard_us = _timed(
        lambda: timing_jax.evaluate_grid(spec, axes, mesh=mesh))
    rows.append(("grid_sharded", shard_us,
                 f"points={shard.size};devices={jax.device_count()};"
                 f"pts_per_s={shard.size / (shard_us * 1e-6):.0f}"))

    # Rung 5: heterogeneous engine-mix lanes (DESIGN.md §13) — per-engine
    # (params, op) blends batched through the same compiled evaluator.
    # Short streams keep every blend on the stacked mixed-lane kernel.
    import dataclasses as _dc

    from repro.core.engine_mix import EngineMix

    mix_reqs = []
    for p in params[: 3 if quick else 6]:
        mp = _dc.replace(p, n=1 << 11)
        for spec_str in ("3r+1w", "2r+2w", "2r+1w+1d"):
            mix = EngineMix.from_spec(spec_str, mp)
            mix_reqs.append(("cont", mp, None, "read", len(mix),
                             "round_robin", 1, "same_channel", mix))
    timing_jax.evaluate_points(spec, mix_reqs)            # compile + place
    _, mix_us = _timed(lambda: timing_jax.evaluate_points(spec, mix_reqs))
    rows.append(("grid_hetero_mix", mix_us,
                 f"points={len(mix_reqs)};"
                 f"pts_per_s={len(mix_reqs) / (mix_us * 1e-6):.0f}"))
    return rows


def bench_oracle_autotune():
    """Framework integration: oracle efficiency + KV layout choice."""
    from repro.core import AccessPattern, MemoryOracle, choose_layout
    oracle = MemoryOracle()

    def run():
        eff = oracle.efficiency(AccessPattern(4096, 4096, 1 << 28))
        lay = choose_layout(oracle, {"seq": 32768, "kv_heads": 8,
                                     "head_dim": 128}, 2,
                            iterate_dim="seq",
                            fetch_dims=("kv_heads", "head_dim"))
        return eff, lay
    (eff, lay), dt = _timed(run)
    return [("oracle_autotune", dt,
             f"seq_eff={eff:.3f};kv_layout={'/'.join(lay.dims)}")]


def bench_roofline(quick):
    """Measured-envelope rungs: the empirical roofline per spec."""
    from repro.core import spec_by_name
    from repro.core.roofline_empirical import measure_envelope

    rows = []
    for name in BENCH_SPEC_NAMES:
        spec = spec_by_name(name)
        env, dt = _timed(lambda: measure_envelope(spec, quick=quick))
        tiers = ";".join(
            f"{''.join(w[0] for w in plc.split('_'))}"
            f"={env.placement_gbps[plc]:.2f}"
            for plc in ("same_channel", "same_switch", "cross_switch"))
        rows.append((f"roofline_envelope_{name}", dt,
                     f"peak_gbps={env.peak_gbps:.2f};"
                     f"knee_ai={env.knee_ai():.0f};{tiers}"))
    return rows


def bench_tune(quick):
    """Layout-autotune rungs, routed through the CampaignService so the
    rung exercises the dedup/coalescing path the tuner ships with.

    Asserts the service invariants on every run: responses ok, reports
    carry a measured winner, duplicate requests coalesce, and the search
    measured no more configs than its candidate space."""
    from repro.service import CampaignService, ExperimentRequest

    svc = CampaignService("sim", "sim")
    rows = []
    for name in BENCH_SPEC_NAMES:
        req = ExperimentRequest.make("layout_autotune", name, quick=quick)
        resp, dt = _timed(lambda: svc.submit(req))
        assert resp.ok, f"layout_autotune[{name}] failed: {resp.error}"
        rep = resp.result
        assert rep.evaluations <= rep.candidates
        rows.append((f"layout_autotune_{name}", dt,
                     f"winner={rep.winner.describe()};"
                     f"gbps={rep.winner_gbps:.2f};"
                     f"evals={rep.evaluations}/{rep.candidates};"
                     f"nominal={rep.nominal_fraction:.2f}"))
        dup, dup_dt = _timed(lambda: svc.submit(req))
        assert dup.coalesced and dup.result == rep
        rows.append((f"layout_autotune_{name}_dedup", dup_dt,
                     "coalesced=True"))
    return rows


def parse_fault_rates(text):
    """Parse the --fault-rate comma list; exits cleanly on bad values."""
    rates = []
    for part in text.split(","):
        part = part.strip()
        try:
            rate = float(part)
        except ValueError:
            raise SystemExit(
                f"benchmarks.run: --fault-rate: {part!r} is not a number "
                f"(expected a comma list like '0,0.01,0.1')")
        if not 0.0 <= rate <= 1.0:
            raise SystemExit(
                f"benchmarks.run: --fault-rate must be in [0, 1], got "
                f"{rate}")
        rates.append(rate)
    if not rates:
        raise SystemExit("benchmarks.run: --fault-rate: empty rate list")
    return tuple(rates)


def _service_request_mix(quick, n_requests):
    """A duplicate-heavy mixed batch over the hbm/ddr4 registry: ~16
    distinct request keys cycled (deterministically shuffled) out to
    `n_requests`, so coalescing has something to prove."""
    import numpy as np

    from repro.service import ExperimentRequest

    templates = []
    for spec in BENCH_SPEC_NAMES:
        templates += [
            ExperimentRequest.make("fig6_address_mapping", spec, quick=True),
            ExperimentRequest.make("table4_idle_latency", spec, n=512),
            ExperimentRequest.make("fig4_refresh", spec, quick=True),
            ExperimentRequest.make("fig7_locality", spec, quick=True),
            ExperimentRequest.make("fig9_channel_contention", spec,
                                   quick=True),
            ExperimentRequest.make("table5_total_throughput", spec, n=2048),
            ExperimentRequest.make("duplex_rw_sweep", spec, quick=True),
            ExperimentRequest.make("contention_scaling_sweep", spec,
                                   quick=True),
            ExperimentRequest.make("engine_mix_sweep", spec, quick=True),
        ]
    reqs = [templates[i % len(templates)] for i in range(n_requests)]
    order = np.random.default_rng(0).permutation(len(reqs))
    return [reqs[i] for i in order]


def bench_service(quick=False, fault_rates=(0.0, 0.01, 0.1),
                  qps_target=None):
    """Campaign-service soak: one row per fault rate (DESIGN.md §10).

    Serves the mixed batch through `CampaignService` with a
    fault-injected sim primary (transient/timeout/corrupt mix) and a
    clean sim fallback, full oracle validation, then asserts the service
    invariants before reporting: zero dropped requests at every rate,
    duplicates coalesced (executed < requests), every response either
    oracle-validated or degraded-with-reason, and >= 1 exercised
    fallback at the highest non-zero rate.
    """
    from repro.core import engine as engine_mod
    from repro.service import (CampaignService, RetryPolicy,
                               register_fault_injected)

    n_requests = 200 if quick else 1000
    requests = _service_request_mix(quick, n_requests)
    max_rate = max(fault_rates)
    rows = []
    for rate in fault_rates:
        primary = f"sim+faults@{rate:g}"
        register_fault_injected(
            "sim", name=primary, rate=rate, seed=7,
            kinds=("transient", "timeout", "corrupt", "unsupported"),
            weights=(0.5, 0.2, 0.15, 0.15), timeout_s=0.2, override=True)
        try:
            svc = CampaignService(
                primary, "sim", retry=RetryPolicy(max_attempts=8),
                validate_fraction=1.0, seed=11)
            responses, dt = _timed(lambda: svc.submit_all(requests))
            st = svc.stats
            assert st.dropped == 0, (
                f"service dropped {st.dropped} requests at rate {rate}")
            assert all(r.ok for r in responses), (
                f"non-ok responses at rate {rate}: "
                f"{[r.error for r in responses if not r.ok][:3]}")
            assert st.executed < st.requests and st.deduped > 0, (
                f"no coalescing at rate {rate}: {st}")
            assert all(r.validated is True or r.validated is None
                       or (r.degraded and r.degraded_reason)
                       for r in responses), (
                f"unvalidated, undegraded response at rate {rate}")
            if rate == max_rate and rate > 0:
                assert st.degraded >= 1, (
                    f"no fallback exercised at rate {rate}: {st}")
            if qps_target is not None:
                assert st.sustained_qps >= qps_target, (
                    f"sustained QPS {st.sustained_qps:.0f} below target "
                    f"{qps_target:.0f} at rate {rate}")
            rows.append((
                f"service_soak_fault{rate:g}", dt,
                f"requests={st.requests};executed={st.executed};"
                f"deduped={st.deduped};retries={st.retries};"
                f"degraded={st.degraded};breaker_opens={st.breaker_opens};"
                f"quarantines={st.quarantines};validated={st.validated};"
                f"dropped={st.dropped};qps={st.sustained_qps:.0f}"))
        finally:
            engine_mod._BACKEND_REGISTRY.pop(primary, None)
    return rows


def bench_lint_report():
    """Timed run of the repro-lint invariant pass (DESIGN.md §11).

    One row per invariant family (`lint_<family>`, analyzer runtime and
    finding count) plus a `lint_total` row carrying the new/stale split
    against the committed `analysis_baseline.json` — so BENCH_lint.json
    tracks both the analyzer's cost and the tree's finding trajectory.
    """
    from repro.analysis import lint as lint_mod
    from repro.analysis.findings import diff_baseline, load_baseline

    root = lint_mod.default_root()
    rows = []
    findings = []
    total_us = 0.0
    for family, runner in lint_mod.FAMILIES:
        family_findings, dt = _timed(lambda runner=runner: runner(root))
        findings.extend(family_findings)
        total_us += dt
        rows.append((f"lint_{family}", dt,
                     f"findings={len(family_findings)}"))
    baseline = load_baseline(root / "analysis_baseline.json")
    diff = diff_baseline(findings, baseline)
    rows.append(("lint_total", total_us,
                 f"findings={len(findings)};baseline={len(baseline)};"
                 f"new={len(diff.new)};stale={len(diff.stale)};"
                 f"clean={diff.clean}"))
    assert diff.clean, (
        f"repro-lint not clean vs analysis_baseline.json: "
        f"{len(diff.new)} new, {len(diff.stale)} stale — run "
        f"`python -m repro.analysis.lint --baseline analysis_baseline.json`")
    return rows


def emit_catalog(target: str) -> None:
    """Print the registry-generated experiment catalog ("-") or splice it
    between the catalog markers of a markdown file (e.g. README.md)."""
    from repro.core.experiments import (CATALOG_BEGIN, CATALOG_END,
                                        catalog_markdown)
    md = catalog_markdown()
    if target == "-":
        print(md)
        return
    with open(target) as f:
        text = f.read()
    lo, hi = text.find(CATALOG_BEGIN), text.find(CATALOG_END)
    if lo < 0 or hi < 0:
        raise SystemExit(
            f"--catalog: {target} has no '{CATALOG_BEGIN}' .. "
            f"'{CATALOG_END}' markers to splice between")
    with open(target, "w") as f:
        f.write(text[:lo] + md + text[hi + len(CATALOG_END):])
    print(f"updated experiment catalog in {target}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a BENCH_*.json perf-trajectory "
                         "file at PATH")
    ap.add_argument("--experiments", metavar="NAMES", default=None,
                    help="comma-separated experiment names to benchmark "
                         "(default: every registered experiment); unknown "
                         "names fail with the registered list")
    ap.add_argument("--engines", metavar="N|MIX", default=None,
                    help="override the engine-count ladder of the "
                         "contention experiments with powers of two up to "
                         "N (e.g. 16 -> 1,2,4,8,16), or — as a mix spec "
                         "like 2r+1w+1d — the custom blend of the "
                         "engine-mix experiments (DESIGN.md §13)")
    ap.add_argument("--arbitration", metavar="POLICY", default=None,
                    choices=("round_robin", "burst", "exclusive"),
                    help="shared-port arbitration granularity for every "
                         "experiment exposing the axis (DESIGN.md §9): "
                         "round_robin, burst, or exclusive")
    ap.add_argument("--burst", type=int, metavar="B", default=None,
                    help="beats per arbitration grant (with "
                         "--arbitration burst)")
    ap.add_argument("--catalog", metavar="PATH", nargs="?", const="-",
                    default=None,
                    help="emit the registry-generated experiment catalog "
                         "and exit: to stdout, or spliced between the "
                         "catalog markers of PATH (e.g. README.md)")
    ap.add_argument("--lint-report", action="store_true",
                    help="time the repro.analysis invariant pass per "
                         "family instead of the registry benches "
                         "(DESIGN.md §11); --json defaults to "
                         "BENCH_lint.json")
    ap.add_argument("--service", action="store_true",
                    help="run the campaign-service fault-injection soak "
                         "instead of the registry benches (DESIGN.md §10)")
    ap.add_argument("--grid", action="store_true",
                    help="run the grid-evaluation ladder (per-point NumPy "
                         "vs jit vs jit+vmap vs sharded, DESIGN.md §12) "
                         "instead of the registry benches; --json defaults "
                         "to BENCH_grid.json")
    ap.add_argument("--roofline", action="store_true",
                    help="run the measured-envelope rungs "
                         "(core/roofline_empirical.py) instead of the "
                         "registry benches; --json defaults to "
                         "BENCH_roofline.json")
    ap.add_argument("--tune", action="store_true",
                    help="run the layout-autotune rungs through the "
                         "campaign service instead of the registry "
                         "benches; --json defaults to BENCH_roofline.json")
    ap.add_argument("--fault-rate", metavar="RATES", default=None,
                    help="comma list of injected fault rates in [0, 1] for "
                         "--service (default: 0,0.01,0.1)")
    ap.add_argument("--qps-target", type=float, metavar="QPS", default=None,
                    help="with --service: fail if sustained QPS falls "
                         "below this at any fault rate")
    args, _ = ap.parse_known_args()
    if not args.service:
        if args.fault_rate is not None:
            ap.error("--fault-rate only applies with --service")
        if args.qps_target is not None:
            ap.error("--qps-target only applies with --service")
    if sum((args.lint_report, args.service, args.grid, args.roofline,
            args.tune)) > 1:
        ap.error("--lint-report, --service, --grid, --roofline and --tune "
                 "are separate modes")
    if args.lint_report and args.json is None:
        args.json = "BENCH_lint.json"
    if args.grid and args.json is None:
        args.json = "BENCH_grid.json"
    if (args.roofline or args.tune) and args.json is None:
        args.json = "BENCH_roofline.json"
    fault_rates = parse_fault_rates(args.fault_rate) \
        if args.fault_rate is not None else (0.0, 0.01, 0.1)
    if args.qps_target is not None and args.qps_target <= 0:
        ap.error(f"--qps-target must be > 0, got {args.qps_target:g}")
    if args.engines is not None:
        args.engines = parse_engines_arg(args.engines)
    if args.burst is not None and args.burst < 1:
        ap.error(f"--burst must be >= 1, got {args.burst}")
    if args.burst is not None and args.arbitration != "burst":
        ap.error("--burst only applies with --arbitration burst "
                 "(round_robin and exclusive fix the grant size)")
    if args.catalog is not None:
        emit_catalog(args.catalog)
        return
    q = args.quick
    if args.json:
        # Fail before the (minutes-long, non-quick) run, not at write time.
        if os.path.isdir(args.json) or args.json.endswith(os.sep):
            ap.error(f"--json: {args.json!r} is a directory, expected a file "
                     "path")
        json_dir = os.path.dirname(os.path.abspath(args.json)) or "."
        if not os.path.isdir(json_dir):
            ap.error(f"--json: directory {json_dir!r} does not exist")
        if not os.access(json_dir, os.W_OK):
            ap.error(f"--json: directory {json_dir!r} is not writable")

    print("name,us_per_call,derived")
    if args.lint_report:
        suites = [bench_lint_report]
    elif args.grid:
        suites = [lambda: bench_grid(q)]
    elif args.service:
        suites = [
            lambda: bench_service(q, fault_rates, args.qps_target),
        ]
    elif args.roofline:
        suites = [lambda: bench_roofline(q)]
    elif args.tune:
        suites = [lambda: bench_tune(q)]
    else:
        suites = [
            lambda: bench_experiments(q, args.experiments, args.engines,
                                      args.arbitration, args.burst),
            lambda: bench_sweep_grid(q),
            bench_table3_resources,
            lambda: bench_tpu_rst_kernel(q),
            bench_oracle_autotune,
        ]
    rows = []
    failures = 0
    t0 = time.perf_counter()
    for suite in suites:
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.0f},{derived}")
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"ERROR,{suite},{type(e).__name__}: {e}", file=sys.stderr)
    wall_us = (time.perf_counter() - t0) * 1e6

    if args.json:
        payload = {
            "benchmark": ("shuhai-lint" if args.lint_report
                          else "shuhai-campaign-service" if args.service
                          else "shuhai-grid" if args.grid
                          else "shuhai-roofline" if args.roofline
                          else "shuhai-tune" if args.tune
                          else "shuhai-campaign"),
            "quick": q,
            "unix_time": time.time(),
            "wall_us": round(wall_us, 1),
            "suite_us_total": round(sum(r["us_per_call"] for r in rows), 1),
            "failures": failures,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
