"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  `us_per_call` is the wall
time of running the suite through the calibrated engine model (the
measurement machinery itself); `derived` carries the headline quantity the
paper reports for that artifact.

With ``--json PATH`` the same rows (plus totals) are written as a
``BENCH_*.json`` perf-trajectory file so successive PRs can track the
sim-backend speedup.

Run: PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    dt = (time.perf_counter() - t0) * 1e6
    return out, dt


def bench_fig4_refresh():
    """Fig. 4: refresh spikes + estimated refresh interval."""
    from repro.core import DDR4, HBM, ShuhaiCampaign
    rows = []
    for spec in (HBM, DDR4):
        camp = ShuhaiCampaign(spec)
        res, dt = _timed(camp.suite_refresh)
        rows.append((f"fig4_refresh_{spec.name}", dt,
                     f"tREFI_est_ns={res['estimated_refresh_interval_ns']:.0f}"))
    return rows


def bench_table4_idle_latency():
    """Table IV: page hit/closed/miss idle latency."""
    from repro.core import DDR4, HBM, ShuhaiCampaign
    rows = []
    for spec in (HBM, DDR4):
        camp = ShuhaiCampaign(spec)
        res, dt = _timed(camp.suite_idle_latency)
        derived = ";".join(f"{k}={v['ns']:.1f}ns" for k, v in res.items())
        rows.append((f"table4_idle_latency_{spec.name}", dt, derived))
    return rows


def bench_fig6_address_mapping(quick=False):
    """Fig. 6: throughput vs (policy, S, B)."""
    from repro.core import DDR4, HBM, ShuhaiCampaign
    rows = []
    strides = (64, 1024, 8192) if quick else (64, 128, 256, 512, 1024,
                                              2048, 4096, 8192, 16384, 32768)
    for spec in (HBM, DDR4):
        camp = ShuhaiCampaign(spec)
        res, dt = _timed(lambda: camp.suite_address_mapping(
            strides=strides, n=1024 if quick else 4096))
        default = "RGBCG" if spec.name == "hbm" else "RCB"
        per_s = res[default][spec.min_burst]
        best_seq = per_s[min(per_s)]
        rows.append((f"fig6_address_mapping_{spec.name}", dt,
                     f"default_seq_gbps={best_seq:.2f};policies={len(res)}"))
    return rows


def bench_fig7_locality(quick=False):
    """Fig. 7: W=8K vs W=256M locality effect."""
    from repro.core import HBM, ShuhaiCampaign
    camp = ShuhaiCampaign(HBM)
    res, dt = _timed(lambda: camp.suite_locality(n=1024 if quick else 4096))
    b, s = HBM.min_burst, 4096
    try:
        local = res[8 * 1024][b][s]
        base = res[256 * 1024 * 1024][b][s]
    except KeyError as e:
        # suite_locality omits RST-invalid (S < B or S > W) combos; the
        # headline point must exist, so a miss is a bug, not a skip.
        raise KeyError(
            f"suite_locality result is missing burst={b} stride={s}: {e}; "
            f"available strides per window: "
            f"{ {w: sorted(per_b.get(b, {})) for w, per_b in res.items()} }"
        ) from e
    return [("fig7_locality_hbm", dt,
             f"w8k_s4k_gbps={local:.2f};w256m_s4k_gbps={base:.2f}")]


def bench_table5_total_throughput():
    """Table V: aggregate throughput, HBM vs DDR4."""
    from repro.core import DDR4, HBM, ShuhaiCampaign
    rows = []
    for spec in (HBM, DDR4):
        camp = ShuhaiCampaign(spec)
        res, dt = _timed(camp.suite_total_throughput)
        rows.append((f"table5_total_{spec.name}", dt,
                     f"total_gbps={res['total_gbps']:.1f};"
                     f"per_channel={res['per_channel_gbps']:.2f}"))
    return rows


def bench_table6_switch_latency():
    """Table VI: AXI channel -> HBM channel 0 latency, switch on."""
    from repro.core import HBM, ShuhaiCampaign
    camp = ShuhaiCampaign(HBM)
    res, dt = _timed(camp.suite_switch_latency)
    spread = res[31]["hit"] - res[0]["hit"]
    return [("table6_switch_latency", dt,
             f"hit_ch0={res[0]['hit']}cyc;hit_ch31={res[31]['hit']}cyc;"
             f"spread={spread}cyc")]


def bench_fig8_switch_throughput():
    """Fig. 8: throughput from one AXI channel per mini-switch."""
    from repro.core import HBM, ShuhaiCampaign
    camp = ShuhaiCampaign(HBM)
    res, dt = _timed(lambda: camp.suite_switch_throughput(strides=(64, 1024)))
    vals = [res[ch][64] for ch in res]
    return [("fig8_switch_throughput", dt,
             f"min_gbps={min(vals):.2f};max_gbps={max(vals):.2f}")]


def bench_table3_resources():
    """Table III analogue: engine 'resource' footprint on TPU = VMEM bytes
    per RST engine tile + params-register bytes (vs FPGA LUTs/BRAM)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    def run():
        tile = ops.tile_bytes(jnp.float32)                 # VMEM per burst
        regs = 2 * 32                                       # 2x256-bit regs
        return {"vmem_tile_bytes": tile, "register_bytes": regs}

    res, dt = _timed(run)
    return [("table3_resources_tpu_analogue", dt,
             f"vmem_tile_bytes={res['vmem_tile_bytes']};"
             f"register_bytes={res['register_bytes']}")]


def bench_tpu_rst_kernel(quick=False):
    """TPU-native RST engines (interpret mode): checksum-validated
    bandwidth samples for sequential vs strided traversals."""
    import jax.numpy as jnp

    from repro.core.params import RSTParams
    from repro.kernels import ops
    n = 32 if quick else 128
    rows = []
    for name, (s_mult, w_tiles) in {
        "seq": (1, 64), "strided4": (4, 64), "hammer": (64, 64),
    }.items():
        tile = ops.tile_bytes(jnp.float32)
        p = RSTParams(n=n, b=tile, s=tile * s_mult, w=tile * w_tiles)
        sample, dt = _timed(
            lambda p=p: ops.measure_read_bandwidth(p, dtype=jnp.float32))
        rows.append((f"tpu_rst_read_{name}", dt,
                     f"bytes={sample.bytes_moved};interp_gbps="
                     f"{sample.gbps:.4f}"))
    return rows


def bench_sweep_grid(quick=False):
    """Sweep planner: one batched (policy x stride x channel) campaign grid,
    exercising memoization + channel broadcast (core/sweep.py)."""
    from repro.core import HBM, RSTParams, Sweep

    strides = (64, 1024) if quick else (64, 256, 1024, 4096)
    channels = range(0, 32, 4)
    n = 1024 if quick else 4096

    def run():
        sweep = Sweep(HBM)
        sweep.add_grid(
            [RSTParams(n=n, b=64, s=s, w=0x10000000) for s in strides],
            policies=("RGBCG", "RBC", "BRC"), channels=tuple(channels))
        results = sweep.run()
        return sweep.stats, results

    (stats, results), dt = _timed(run)
    gbps = [r.value.gbps for r in results]
    return [("sweep_grid_hbm", dt,
             f"points={stats.points};evaluated={stats.evaluated};"
             f"cache_hits={stats.cache_hits};max_gbps={max(gbps):.2f}")]


def bench_oracle_autotune():
    """Framework integration: oracle efficiency + KV layout choice."""
    from repro.core import AccessPattern, MemoryOracle, choose_layout
    oracle = MemoryOracle()

    def run():
        eff = oracle.efficiency(AccessPattern(4096, 4096, 1 << 28))
        lay = choose_layout(oracle, {"seq": 32768, "kv_heads": 8,
                                     "head_dim": 128}, 2,
                            iterate_dim="seq",
                            fetch_dims=("kv_heads", "head_dim"))
        return eff, lay
    (eff, lay), dt = _timed(run)
    return [("oracle_autotune", dt,
             f"seq_eff={eff:.3f};kv_layout={'/'.join(lay.dims)}")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a BENCH_*.json perf-trajectory "
                         "file at PATH")
    args, _ = ap.parse_known_args()
    q = args.quick
    if args.json:
        # Fail before the (minutes-long, non-quick) run, not at write time.
        if os.path.isdir(args.json) or args.json.endswith(os.sep):
            ap.error(f"--json: {args.json!r} is a directory, expected a file "
                     "path")
        json_dir = os.path.dirname(os.path.abspath(args.json)) or "."
        if not os.path.isdir(json_dir):
            ap.error(f"--json: directory {json_dir!r} does not exist")
        if not os.access(json_dir, os.W_OK):
            ap.error(f"--json: directory {json_dir!r} is not writable")

    print("name,us_per_call,derived")
    suites = [
        bench_fig4_refresh,
        bench_table4_idle_latency,
        lambda: bench_fig6_address_mapping(q),
        lambda: bench_fig7_locality(q),
        bench_table5_total_throughput,
        bench_table6_switch_latency,
        bench_fig8_switch_throughput,
        lambda: bench_sweep_grid(q),
        bench_table3_resources,
        lambda: bench_tpu_rst_kernel(q),
        bench_oracle_autotune,
    ]
    rows = []
    failures = 0
    t0 = time.perf_counter()
    for suite in suites:
        try:
            for name, us, derived in suite():
                print(f"{name},{us:.0f},{derived}")
                rows.append({"name": name, "us_per_call": round(us, 1),
                             "derived": derived})
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"ERROR,{suite},{type(e).__name__}: {e}", file=sys.stderr)
    wall_us = (time.perf_counter() - t0) * 1e6

    if args.json:
        payload = {
            "benchmark": "shuhai-campaign",
            "quick": q,
            "unix_time": time.time(),
            "wall_us": round(wall_us, 1),
            "suite_us_total": round(sum(r["us_per_call"] for r in rows), 1),
            "failures": failures,
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
